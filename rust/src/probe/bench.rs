//! The offline probe benchmark (paper §4.1): measure `g` and `ℓ` with
//! total-exchange h-relations, fill the Θ(1) table behind `lpf_probe`,
//! and produce the rows of Table 3.
//!
//! Estimators, exactly as the paper defines them:
//! * `g = (T(n_max) − T(2p)) / (n_max − 2p)` — asymptotic per-word cost;
//! * `ℓ = max{ T(0), 2·T(p) − T(2p) }` — fixed cost, shielded against the
//!   "sensitive to small deviations" problem by sampling repeatedly;
//! * both normalised by `r`, the measured memcpy speed, for the table.

use std::sync::Arc;
use std::time::Instant;

use crate::benchkit::Samples;
use crate::core::machine::BspParams;
use crate::core::{Args, Result, MSG_DEFAULT, SYNC_DEFAULT};
use crate::ctx::{Context, Platform};
use crate::fabric::{ProtocolConfig, ProtocolTier};
use crate::pool::Pool;
use crate::probe::ProbeTable;

/// Configuration for one probe run.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Processes.
    pub p: u32,
    /// Word sizes to measure (Table 3 uses 8, 64, 1024, 1 MiB).
    pub word_sizes: Vec<usize>,
    /// Maximum per-process h-relation volume in bytes ("at least four
    /// times the cache" in the paper; scaled to this container).
    pub max_bytes: usize,
    /// Timed repetitions per measurement point.
    pub reps: u32,
    /// Samples per point (outer loop; Table 3's CIs come from these).
    pub samples: u32,
}

impl ProbeConfig {
    /// Container-scaled defaults.
    pub fn quick(p: u32) -> ProbeConfig {
        ProbeConfig {
            p,
            word_sizes: vec![8, 64, 1024, 1 << 20],
            max_bytes: 4 << 20,
            reps: 3,
            samples: 5,
        }
    }
}

/// Measure the mean time (ns) of a total-exchange where every process
/// sends and receives `h` words of `word_bytes` each. Uses wall-clock on
/// real fabrics and the simulated clock on netsim fabrics.
///
/// One-shot convenience over [`measure_exchange_on`]; the probe sweep
/// itself runs its hundreds of measurement jobs on one shared [`Pool`] so
/// process spawn stays off the measured path.
pub fn measure_exchange(
    platform: &Platform,
    p: u32,
    word_bytes: usize,
    h: usize,
    reps: u32,
) -> Result<f64> {
    let pool = Pool::new(platform.clone(), p);
    measure_exchange_on(&pool, word_bytes, h, reps)
}

/// Which peers a probe exchange addresses — the lever behind the
/// per-level `(g, ℓ)` fits on hierarchical topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerClass {
    /// Every other process (the paper's flat total exchange).
    All,
    /// Only peers on the same topology node (shared-memory links).
    Intra,
    /// Only peers on other nodes (wire links).
    Inter,
}

/// [`measure_exchange`] as one warm job on a shared pool.
pub fn measure_exchange_on(pool: &Pool, word_bytes: usize, h: usize, reps: u32) -> Result<f64> {
    measure_exchange_classed(pool, word_bytes, h, reps, PeerClass::All)
}

/// [`measure_exchange_on`] restricted to one [`PeerClass`]: the h words
/// split evenly over the eligible peers only (node membership read from
/// the fabric's topology view). With no eligible peer the exchange is
/// empty and the measurement reduces to the superstep fixed cost.
pub fn measure_exchange_classed(
    pool: &Pool,
    word_bytes: usize,
    h: usize,
    reps: u32,
    class: PeerClass,
) -> Result<f64> {
    let outs = pool.exec(
        move |ctx: &mut Context, _| -> Result<f64> {
            let p = ctx.p();
            let bytes = h * word_bytes;
            ctx.resize_memory_register(2)?;
            ctx.resize_message_queue(2 * (h + p as usize))?;
            ctx.sync(SYNC_DEFAULT)?;
            let src = ctx.register_global(bytes.max(1))?;
            let dst = ctx.register_global(bytes.max(1))?;
            ctx.sync(SYNC_DEFAULT)?;
            let q = ctx.topology().procs_per_node.max(1);
            // balanced exchange: my h words split evenly over the
            // eligible peers of the requested class
            let issue = move |ctx: &mut Context| -> Result<()> {
                let me = ctx.pid();
                let eligible: Vec<u32> = (0..p)
                    .filter(|&d| d != me)
                    .filter(|&d| match class {
                        PeerClass::All => true,
                        PeerClass::Intra => d / q == me / q,
                        PeerClass::Inter => d / q != me / q,
                    })
                    .collect();
                if eligible.is_empty() || h == 0 {
                    return Ok(());
                }
                let per_peer = h / eligible.len();
                let rem = h % eligible.len();
                let mut off = 0usize;
                for (k, &d) in eligible.iter().enumerate() {
                    let words = per_peer + usize::from(k < rem);
                    if words == 0 {
                        continue;
                    }
                    ctx.put(src, off, d, dst, off, words * word_bytes, MSG_DEFAULT)?;
                    off += words * word_bytes;
                }
                Ok(())
            };
            // warm + settle
            issue(ctx)?;
            ctx.sync(SYNC_DEFAULT)?;
            let sim_before = ctx.sim_time_ns();
            let wall = Instant::now();
            for _ in 0..reps {
                issue(ctx)?;
                ctx.sync(SYNC_DEFAULT)?;
            }
            let ns = match (sim_before, ctx.sim_time_ns()) {
                (Some(b), Some(a)) => (a - b) / reps as f64,
                _ => wall.elapsed().as_nanos() as f64 / reps as f64,
            };
            Ok(ns)
        },
        Args::none(),
    )?;
    let per_pid: Result<Vec<f64>> = outs.into_iter().collect();
    let per_pid = per_pid?;
    // BSP time of the h-relation = the slowest process
    Ok(per_pid.iter().copied().fold(0.0, f64::max))
}

/// Measured memcpy speed in ns/byte (Table 3's normaliser `r`).
pub fn measure_memcpy_r(bytes: usize, reps: u32) -> f64 {
    let src = vec![7u8; bytes];
    let mut dst = vec![0u8; bytes];
    // warm
    dst.copy_from_slice(&src);
    let t = Instant::now();
    for _ in 0..reps {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    }
    t.elapsed().as_nanos() as f64 / (reps as f64 * bytes as f64)
}

/// One Table-3 row: `(g, ℓ)` for a word size, with confidence intervals.
#[derive(Debug, Clone)]
pub struct ProbeRow {
    pub word_bytes: usize,
    pub g_ns: f64,
    pub g_ci: f64,
    pub l_ns: f64,
    pub l_ci: f64,
}

/// The paper's Table-3 fit for one word size and one peer class:
/// `g` from the asymptotic slope, `ℓ` from the small-h intercept,
/// `samples` independent estimates each.
fn fit_row(pool: &Pool, cfg: &ProbeConfig, w: usize, class: PeerClass) -> Result<ProbeRow> {
    let p = cfg.p;
    let n_max = (cfg.max_bytes / w).max(4 * p as usize);
    let mut gs = Vec::new();
    let mut ls = Vec::new();
    for _ in 0..cfg.samples {
        let t0 = measure_exchange_classed(pool, w, 0, cfg.reps, class)?;
        let tp = measure_exchange_classed(pool, w, p as usize, cfg.reps, class)?;
        let t2p = measure_exchange_classed(pool, w, 2 * p as usize, cfg.reps, class)?;
        let tmax = measure_exchange_classed(pool, w, n_max, cfg.reps, class)?;
        let g = (tmax - t2p) / (n_max - 2 * p as usize) as f64;
        let l = f64::max(t0, 2.0 * tp - t2p);
        gs.push(g.max(0.0));
        ls.push(l.max(0.0));
    }
    let gs = Samples::from(gs);
    let ls = Samples::from(ls);
    Ok(ProbeRow {
        word_bytes: w,
        g_ns: gs.mean(),
        g_ci: gs.ci95(),
        l_ns: ls.mean(),
        l_ci: ls.ci95(),
    })
}

/// Run the full offline probe for one platform; records the rows into
/// `table` (keyed by the backend name) and returns them with the measured
/// memcpy speed `r` (ns/byte).
pub fn run_offline_probe(
    platform: &Platform,
    cfg: &ProbeConfig,
    table: &Arc<ProbeTable>,
) -> Result<(Vec<ProbeRow>, f64)> {
    let backend = platform.make_fabric(1).name();
    let r = measure_memcpy_r(cfg.max_bytes.min(8 << 20), 5);
    let p = cfg.p;
    // One warm team serves the whole sweep (4 × samples × word-size jobs):
    // the measured intervals never include process spawn or fabric build.
    let pool = Pool::new(platform.clone(), p);
    let mut rows = Vec::new();
    for &w in &cfg.word_sizes {
        let row = fit_row(&pool, cfg, w, PeerClass::All)?;
        table.record(
            backend,
            p,
            BspParams { word_bytes: w, g_ns: row.g_ns, l_ns: row.l_ns },
            r,
        );
        rows.push(row);
    }
    Ok((rows, r))
}

/// Per-level `(g, ℓ)` fits for a hierarchical platform (the probe learns
/// what each topology *level* costs, not one blended number). Runs the
/// Table-3 estimators twice with the exchange restricted to
/// [`PeerClass::Intra`] and [`PeerClass::Inter`] peers, recording the
/// fits under `"<backend>/intra"` and `"<backend>/inter"`.
/// On a flat (single-level) platform there is nothing to separate and
/// the result is empty.
///
/// **Deprecation note (ISSUE 10):** these un-tiered keys are the
/// *rendezvous*-tier fits (the pool runs the default protocol config,
/// which selects rendezvous for every descriptor). [`fitted_protocol`]
/// records tier-resolved fits under `"<backend>/{intra,inter}/{eager,
/// rdv}"`; the old keys remain written for one release so existing
/// table readers keep working, then consumers should move to the
/// `/rdv`-suffixed keys.
pub fn run_level_probe(
    platform: &Platform,
    cfg: &ProbeConfig,
    table: &Arc<ProbeTable>,
) -> Result<Vec<(String, Vec<ProbeRow>)>> {
    let p = cfg.p;
    let fabric = platform.make_fabric(p);
    let topo = fabric.topology();
    if topo.levels < 2 || topo.procs_per_node < 2 {
        return Ok(Vec::new());
    }
    let backend = fabric.name();
    let r = measure_memcpy_r(cfg.max_bytes.min(8 << 20), 5);
    let pool = Pool::new(platform.clone(), p);
    let mut out = Vec::new();
    for (level, class) in [("intra", PeerClass::Intra), ("inter", PeerClass::Inter)] {
        let key = format!("{backend}/{level}");
        let mut rows = Vec::new();
        for &w in &cfg.word_sizes {
            let row = fit_row(&pool, cfg, w, class)?;
            table.record(
                &key,
                p,
                BspParams { word_bytes: w, g_ns: row.g_ns, l_ns: row.l_ns },
                r,
            );
            rows.push(row);
        }
        out.push((key, rows));
    }
    Ok(out)
}

/// The fitted eager/rendezvous crossover, in payload bytes per
/// descriptor, from one rendezvous-tier and one eager-tier probe fit of
/// the same exchange shape (`descs` = descriptors per process in that
/// shape, i.e. eligible peers — the balanced exchange coalesces each
/// peer's run into one descriptor).
///
/// Both tiers pay the route's per-byte transit, so the lines differ by
/// * what eager *saves*: the handshake fixed costs, which the Table-3
///   intercept `ℓ` absorbs (`descs` handshake messages + one conditional
///   handshake latency per superstep) — `Δℓ = ℓ_rdv − ℓ_eager`, divided
///   by `descs` to land per descriptor;
/// * what eager *pays*: the receiver-side bounce copy (and pre-trim
///   transit of bytes the CRCW resolution would have trimmed — zero in
///   the probe's disjoint exchange), which the slope `g` absorbs —
///   `Δg = g_eager − g_rdv` per byte.
///
/// The crossover is `Δℓ / (descs · Δg)`: below it an eager descriptor is
/// cheaper, above it rendezvous wins. Degenerate fits degrade safely:
/// no measured saving (`Δℓ ≤ 0`) disables the eager tier (0); no
/// measured penalty (`Δg ≤ 0`) means eager won at every size the fit
/// saw, and the crossover is unbounded (`u64::MAX`).
pub fn crossover_bytes(rdv: &ProbeRow, eager: &ProbeRow, descs: u64) -> u64 {
    let dl = (rdv.l_ns - eager.l_ns) / descs.max(1) as f64;
    let dg = eager.g_ns / eager.word_bytes as f64 - rdv.g_ns / rdv.word_bytes as f64;
    if dl <= 0.0 {
        0
    } else if dg <= 0.0 {
        u64::MAX
    } else {
        (dl / dg) as u64
    }
}

/// Fit the per-fabric (and, on hierarchical topologies, per-level)
/// eager/rendezvous crossover from measured `(g, ℓ)` and return the
/// [`ProtocolConfig`] the probe would install — the tentpole's "tier
/// thresholds are fitted, not magic" contract. Runs the Table-3
/// estimators at the smallest configured word size once per `{peer
/// class} × {forced tier}` cell (the pool pinned to
/// [`ProtocolConfig::forced`] for each), records every cell into `table`
/// under the tier-resolved keys `"<backend>/{intra,inter}/{eager,rdv}"`
/// (flat fabrics: `"<backend>/{eager,rdv}"`), and folds the crossovers
/// into an `Auto` config via [`crossover_bytes`].
pub fn fitted_protocol(
    platform: &Platform,
    cfg: &ProbeConfig,
    table: &Arc<ProbeTable>,
) -> Result<ProtocolConfig> {
    let p = cfg.p;
    let fabric = platform.make_fabric(p);
    let backend = fabric.name();
    let topo = fabric.topology();
    let hier = topo.levels >= 2 && topo.procs_per_node >= 2;
    let q = topo.procs_per_node.max(1);
    let r = measure_memcpy_r(cfg.max_bytes.min(8 << 20), 5);
    let pool = Pool::new(platform.clone(), p);
    let w = cfg.word_sizes.iter().copied().min().unwrap_or(8);
    // (level label or "" for flat, peer class, descriptors per process)
    let levels: Vec<(&str, PeerClass, u64)> = if hier {
        vec![
            ("intra", PeerClass::Intra, (q - 1) as u64),
            ("inter", PeerClass::Inter, (p - q) as u64),
        ]
    } else {
        vec![("", PeerClass::All, (p - 1) as u64)]
    };
    let mut cross = [0u64; 2]; // [intra, inter]
    for (i, (level, class, descs)) in levels.iter().enumerate() {
        let mut per_tier = Vec::with_capacity(2);
        for (tname, tier) in
            [("rdv", ProtocolTier::Rendezvous), ("eager", ProtocolTier::Eager)]
        {
            pool.set_protocol(ProtocolConfig::forced(tier));
            let row = fit_row(&pool, cfg, w, *class)?;
            let key = if level.is_empty() {
                format!("{backend}/{tname}")
            } else {
                format!("{backend}/{level}/{tname}")
            };
            table.record(
                &key,
                p,
                BspParams { word_bytes: w, g_ns: row.g_ns, l_ns: row.l_ns },
                r,
            );
            per_tier.push(row);
        }
        let c = crossover_bytes(&per_tier[0], &per_tier[1], *descs);
        if hier {
            cross[i] = c;
        } else {
            cross = [c, c];
        }
    }
    Ok(ProtocolConfig::auto(cross[0], cross[1]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memcpy_r_is_sane() {
        let r = measure_memcpy_r(1 << 20, 3);
        assert!(r > 0.001 && r < 100.0, "r = {r} ns/byte");
    }

    #[test]
    fn exchange_time_grows_with_h() {
        // medians over several attempts: wall-clock on a single core that
        // is concurrently running the rest of the suite is noisy
        let plat = Platform::shared().checked(false);
        let med = |h: usize| {
            let mut v: Vec<f64> =
                (0..5).map(|_| measure_exchange(&plat, 2, 8, h, 2).unwrap()).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[2]
        };
        let t_small = med(16);
        let t_large = med(1 << 18);
        assert!(t_large > t_small, "{t_large} vs {t_small}");
    }

    #[test]
    fn sim_fabric_reports_sim_time() {
        let plat = Platform::rdma();
        let t = measure_exchange(&plat, 4, 8, 256, 1).unwrap();
        let t2 = measure_exchange(&plat, 4, 8, 256, 1).unwrap();
        assert!(t > 0.0);
        assert_eq!(t, t2, "netsim must be deterministic");
    }

    /// The per-level probe separates what the blended flat fit mixes:
    /// on the hybrid fabric intra-node links price at the shared-memory
    /// personality (expensive per byte, cheap latency) and inter-node
    /// at the wire personality — the simulated clock is deterministic,
    /// so the ordering of the fitted slopes is exact, not statistical.
    #[test]
    fn level_probe_fits_each_level() {
        let table = Arc::new(ProbeTable::default());
        let cfg = ProbeConfig {
            p: 4,
            word_sizes: vec![8],
            max_bytes: 1 << 16,
            reps: 1,
            samples: 1,
        };
        let levels = run_level_probe(&Platform::hybrid(2), &cfg, &table).unwrap();
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[0].0, "hybrid/intra");
        assert_eq!(levels[1].0, "hybrid/inter");
        let g_intra = levels[0].1[0].g_ns;
        let g_inter = levels[1].1[0].g_ns;
        assert!(g_intra > 0.0 && g_inter > 0.0, "{g_intra} / {g_inter}");
        // shm memcpy per byte (0.35 ns) > one wire hop (0.143 ns): the
        // intra slope must come out strictly steeper
        assert!(g_intra > g_inter, "intra {g_intra} vs inter {g_inter}");
        // both levels landed in the table under their own keys
        assert_eq!(table.lookup("hybrid/intra", 4).params.len(), 1);
        assert_eq!(table.lookup("hybrid/inter", 4).params.len(), 1);
        // a flat platform has no levels to separate
        assert!(run_level_probe(&Platform::rdma(), &cfg, &table).unwrap().is_empty());
    }

    /// The fitted protocol config (ISSUE 10): per-tier probe fits land
    /// under the tier-resolved keys, the old un-tiered keys keep being
    /// written by `run_level_probe` (deprecated, one release), and the
    /// crossover comes out of the measured costs with the right sign —
    /// on the simulated RDMA wire an eager descriptor saves the 16-byte
    /// handshake and its latency but pays the receiver bounce copy, so
    /// the fitted crossover is finite and strictly positive.
    #[test]
    fn fitted_protocol_fits_tier_crossover() {
        let table = Arc::new(ProbeTable::default());
        let cfg = ProbeConfig {
            p: 4,
            word_sizes: vec![8],
            max_bytes: 1 << 16,
            reps: 1,
            samples: 1,
        };
        // flat fabric: one crossover, both thresholds
        let proto = fitted_protocol(&Platform::rdma(), &cfg, &table).unwrap();
        assert_eq!(proto.policy, crate::fabric::ProtocolPolicy::Auto);
        assert_eq!(proto.eager_max_intra, proto.eager_max_inter);
        assert!(
            proto.eager_max_inter > 0 && proto.eager_max_inter < u64::MAX,
            "crossover {} must be finite and positive",
            proto.eager_max_inter
        );
        assert_eq!(table.lookup("rdma/rdv", 4).params.len(), 1);
        assert_eq!(table.lookup("rdma/eager", 4).params.len(), 1);
        // hierarchical fabric: per-level tier keys
        let proto = fitted_protocol(&Platform::hybrid(2), &cfg, &table).unwrap();
        assert_eq!(proto.policy, crate::fabric::ProtocolPolicy::Auto);
        for key in ["hybrid/intra/rdv", "hybrid/intra/eager", "hybrid/inter/rdv", "hybrid/inter/eager"]
        {
            assert_eq!(table.lookup(key, 4).params.len(), 1, "missing tier fit {key}");
        }
        // the deprecated un-tiered level keys are still written
        run_level_probe(&Platform::hybrid(2), &cfg, &table).unwrap();
        assert_eq!(table.lookup("hybrid/intra", 4).params.len(), 1);
    }

    /// The crossover arithmetic on hand-built fits: Δℓ pays for Δg.
    #[test]
    fn crossover_bytes_handles_degenerate_fits() {
        let row = |g_ns: f64, l_ns: f64| ProbeRow {
            word_bytes: 1,
            g_ns,
            g_ci: 0.0,
            l_ns,
            l_ci: 0.0,
        };
        // eager saves 300 ns of fixed cost over 3 descriptors, pays an
        // extra 0.5 ns/byte: crossover = (300/3) / 0.5 = 200 bytes
        assert_eq!(crossover_bytes(&row(1.0, 500.0), &row(1.5, 200.0), 3), 200);
        // no fixed saving: the eager tier is disabled
        assert_eq!(crossover_bytes(&row(1.0, 200.0), &row(1.5, 200.0), 3), 0);
        // no per-byte penalty either way: eager always wins
        assert_eq!(crossover_bytes(&row(1.0, 500.0), &row(1.0, 200.0), 3), u64::MAX);
    }

    #[test]
    fn offline_probe_fills_table() {
        let table = Arc::new(ProbeTable::default());
        let cfg = ProbeConfig {
            p: 2,
            word_sizes: vec![8, 1024],
            max_bytes: 1 << 16,
            reps: 1,
            samples: 2,
        };
        let (rows, r) =
            run_offline_probe(&Platform::shared().checked(false), &cfg, &table).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(r > 0.0);
        let m = table.lookup("shared", 2);
        assert_eq!(m.params.len(), 2);
        assert!(m.h_relation_ns(100, 8) > 0.0);
    }
}
