//! The offline probe benchmark (paper §4.1): measure `g` and `ℓ` with
//! total-exchange h-relations, fill the Θ(1) table behind `lpf_probe`,
//! and produce the rows of Table 3.
//!
//! Estimators, exactly as the paper defines them:
//! * `g = (T(n_max) − T(2p)) / (n_max − 2p)` — asymptotic per-word cost;
//! * `ℓ = max{ T(0), 2·T(p) − T(2p) }` — fixed cost, shielded against the
//!   "sensitive to small deviations" problem by sampling repeatedly;
//! * both normalised by `r`, the measured memcpy speed, for the table.

use std::sync::Arc;
use std::time::Instant;

use crate::benchkit::Samples;
use crate::core::machine::BspParams;
use crate::core::{Args, Result, MSG_DEFAULT, SYNC_DEFAULT};
use crate::ctx::{Context, Platform};
use crate::pool::Pool;
use crate::probe::ProbeTable;

/// Configuration for one probe run.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Processes.
    pub p: u32,
    /// Word sizes to measure (Table 3 uses 8, 64, 1024, 1 MiB).
    pub word_sizes: Vec<usize>,
    /// Maximum per-process h-relation volume in bytes ("at least four
    /// times the cache" in the paper; scaled to this container).
    pub max_bytes: usize,
    /// Timed repetitions per measurement point.
    pub reps: u32,
    /// Samples per point (outer loop; Table 3's CIs come from these).
    pub samples: u32,
}

impl ProbeConfig {
    /// Container-scaled defaults.
    pub fn quick(p: u32) -> ProbeConfig {
        ProbeConfig {
            p,
            word_sizes: vec![8, 64, 1024, 1 << 20],
            max_bytes: 4 << 20,
            reps: 3,
            samples: 5,
        }
    }
}

/// Measure the mean time (ns) of a total-exchange where every process
/// sends and receives `h` words of `word_bytes` each. Uses wall-clock on
/// real fabrics and the simulated clock on netsim fabrics.
///
/// One-shot convenience over [`measure_exchange_on`]; the probe sweep
/// itself runs its hundreds of measurement jobs on one shared [`Pool`] so
/// process spawn stays off the measured path.
pub fn measure_exchange(
    platform: &Platform,
    p: u32,
    word_bytes: usize,
    h: usize,
    reps: u32,
) -> Result<f64> {
    let pool = Pool::new(platform.clone(), p);
    measure_exchange_on(&pool, word_bytes, h, reps)
}

/// [`measure_exchange`] as one warm job on a shared pool.
pub fn measure_exchange_on(pool: &Pool, word_bytes: usize, h: usize, reps: u32) -> Result<f64> {
    let outs = pool.exec(
        move |ctx: &mut Context, _| -> Result<f64> {
            let p = ctx.p();
            let bytes = h * word_bytes;
            ctx.resize_memory_register(2)?;
            ctx.resize_message_queue(2 * (h + p as usize))?;
            ctx.sync(SYNC_DEFAULT)?;
            let src = ctx.register_global(bytes.max(1))?;
            let dst = ctx.register_global(bytes.max(1))?;
            ctx.sync(SYNC_DEFAULT)?;
            // balanced total exchange: my h words split evenly over peers
            let issue = |ctx: &mut Context| -> Result<()> {
                if p == 1 || h == 0 {
                    return Ok(());
                }
                let peers = p - 1;
                let per_peer = h / peers as usize;
                let rem = h % peers as usize;
                let mut off = 0usize;
                let mut k = 0u32;
                for d in 0..p {
                    if d == ctx.pid() {
                        continue;
                    }
                    let words = per_peer + usize::from((k as usize) < rem);
                    k += 1;
                    if words == 0 {
                        continue;
                    }
                    ctx.put(src, off, d, dst, off, words * word_bytes, MSG_DEFAULT)?;
                    off += words * word_bytes;
                }
                Ok(())
            };
            // warm + settle
            issue(ctx)?;
            ctx.sync(SYNC_DEFAULT)?;
            let sim_before = ctx.sim_time_ns();
            let wall = Instant::now();
            for _ in 0..reps {
                issue(ctx)?;
                ctx.sync(SYNC_DEFAULT)?;
            }
            let ns = match (sim_before, ctx.sim_time_ns()) {
                (Some(b), Some(a)) => (a - b) / reps as f64,
                _ => wall.elapsed().as_nanos() as f64 / reps as f64,
            };
            Ok(ns)
        },
        Args::none(),
    )?;
    let per_pid: Result<Vec<f64>> = outs.into_iter().collect();
    let per_pid = per_pid?;
    // BSP time of the h-relation = the slowest process
    Ok(per_pid.iter().copied().fold(0.0, f64::max))
}

/// Measured memcpy speed in ns/byte (Table 3's normaliser `r`).
pub fn measure_memcpy_r(bytes: usize, reps: u32) -> f64 {
    let src = vec![7u8; bytes];
    let mut dst = vec![0u8; bytes];
    // warm
    dst.copy_from_slice(&src);
    let t = Instant::now();
    for _ in 0..reps {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    }
    t.elapsed().as_nanos() as f64 / (reps as f64 * bytes as f64)
}

/// One Table-3 row: `(g, ℓ)` for a word size, with confidence intervals.
#[derive(Debug, Clone)]
pub struct ProbeRow {
    pub word_bytes: usize,
    pub g_ns: f64,
    pub g_ci: f64,
    pub l_ns: f64,
    pub l_ci: f64,
}

/// Run the full offline probe for one platform; records the rows into
/// `table` (keyed by the backend name) and returns them with the measured
/// memcpy speed `r` (ns/byte).
pub fn run_offline_probe(
    platform: &Platform,
    cfg: &ProbeConfig,
    table: &Arc<ProbeTable>,
) -> Result<(Vec<ProbeRow>, f64)> {
    let backend = platform.make_fabric(1).name();
    let r = measure_memcpy_r(cfg.max_bytes.min(8 << 20), 5);
    let p = cfg.p;
    // One warm team serves the whole sweep (4 × samples × word-size jobs):
    // the measured intervals never include process spawn or fabric build.
    let pool = Pool::new(platform.clone(), p);
    let mut rows = Vec::new();
    for &w in &cfg.word_sizes {
        let n_max = (cfg.max_bytes / w).max(4 * p as usize);
        let mut gs = Vec::new();
        let mut ls = Vec::new();
        for _ in 0..cfg.samples {
            let t0 = measure_exchange_on(&pool, w, 0, cfg.reps)?;
            let tp = measure_exchange_on(&pool, w, p as usize, cfg.reps)?;
            let t2p = measure_exchange_on(&pool, w, 2 * p as usize, cfg.reps)?;
            let tmax = measure_exchange_on(&pool, w, n_max, cfg.reps)?;
            let g = (tmax - t2p) / (n_max - 2 * p as usize) as f64;
            let l = f64::max(t0, 2.0 * tp - t2p);
            gs.push(g.max(0.0));
            ls.push(l.max(0.0));
        }
        let gs = Samples::from(gs);
        let ls = Samples::from(ls);
        let row = ProbeRow {
            word_bytes: w,
            g_ns: gs.mean(),
            g_ci: gs.ci95(),
            l_ns: ls.mean(),
            l_ci: ls.ci95(),
        };
        table.record(
            backend,
            p,
            BspParams { word_bytes: w, g_ns: row.g_ns, l_ns: row.l_ns },
            r,
        );
        rows.push(row);
    }
    Ok((rows, r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memcpy_r_is_sane() {
        let r = measure_memcpy_r(1 << 20, 3);
        assert!(r > 0.001 && r < 100.0, "r = {r} ns/byte");
    }

    #[test]
    fn exchange_time_grows_with_h() {
        // medians over several attempts: wall-clock on a single core that
        // is concurrently running the rest of the suite is noisy
        let plat = Platform::shared().checked(false);
        let med = |h: usize| {
            let mut v: Vec<f64> =
                (0..5).map(|_| measure_exchange(&plat, 2, 8, h, 2).unwrap()).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[2]
        };
        let t_small = med(16);
        let t_large = med(1 << 18);
        assert!(t_large > t_small, "{t_large} vs {t_small}");
    }

    #[test]
    fn sim_fabric_reports_sim_time() {
        let plat = Platform::rdma();
        let t = measure_exchange(&plat, 4, 8, 256, 1).unwrap();
        let t2 = measure_exchange(&plat, 4, 8, 256, 1).unwrap();
        assert!(t > 0.0);
        assert_eq!(t, t2, "netsim must be deterministic");
    }

    #[test]
    fn offline_probe_fills_table() {
        let table = Arc::new(ProbeTable::default());
        let cfg = ProbeConfig {
            p: 2,
            word_sizes: vec![8, 1024],
            max_bytes: 1 << 16,
            reps: 1,
            samples: 2,
        };
        let (rows, r) =
            run_offline_probe(&Platform::shared().checked(false), &cfg, &table).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(r > 0.0);
        let m = table.lookup("shared", 2);
        assert_eq!(m.params.len(), 2);
        assert!(m.h_relation_ns(100, 8) > 0.0);
    }
}
