//! The offline probe benchmark (paper §4.1): measure `g` and `ℓ` with
//! total-exchange h-relations, fill the Θ(1) table behind `lpf_probe`,
//! and produce the rows of Table 3.
//!
//! Estimators, exactly as the paper defines them:
//! * `g = (T(n_max) − T(2p)) / (n_max − 2p)` — asymptotic per-word cost;
//! * `ℓ = max{ T(0), 2·T(p) − T(2p) }` — fixed cost, shielded against the
//!   "sensitive to small deviations" problem by sampling repeatedly;
//! * both normalised by `r`, the measured memcpy speed, for the table.

use std::sync::Arc;
use std::time::Instant;

use crate::benchkit::Samples;
use crate::core::machine::BspParams;
use crate::core::{Args, Result, MSG_DEFAULT, SYNC_DEFAULT};
use crate::ctx::{Context, Platform};
use crate::pool::Pool;
use crate::probe::ProbeTable;

/// Configuration for one probe run.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Processes.
    pub p: u32,
    /// Word sizes to measure (Table 3 uses 8, 64, 1024, 1 MiB).
    pub word_sizes: Vec<usize>,
    /// Maximum per-process h-relation volume in bytes ("at least four
    /// times the cache" in the paper; scaled to this container).
    pub max_bytes: usize,
    /// Timed repetitions per measurement point.
    pub reps: u32,
    /// Samples per point (outer loop; Table 3's CIs come from these).
    pub samples: u32,
}

impl ProbeConfig {
    /// Container-scaled defaults.
    pub fn quick(p: u32) -> ProbeConfig {
        ProbeConfig {
            p,
            word_sizes: vec![8, 64, 1024, 1 << 20],
            max_bytes: 4 << 20,
            reps: 3,
            samples: 5,
        }
    }
}

/// Measure the mean time (ns) of a total-exchange where every process
/// sends and receives `h` words of `word_bytes` each. Uses wall-clock on
/// real fabrics and the simulated clock on netsim fabrics.
///
/// One-shot convenience over [`measure_exchange_on`]; the probe sweep
/// itself runs its hundreds of measurement jobs on one shared [`Pool`] so
/// process spawn stays off the measured path.
pub fn measure_exchange(
    platform: &Platform,
    p: u32,
    word_bytes: usize,
    h: usize,
    reps: u32,
) -> Result<f64> {
    let pool = Pool::new(platform.clone(), p);
    measure_exchange_on(&pool, word_bytes, h, reps)
}

/// Which peers a probe exchange addresses — the lever behind the
/// per-level `(g, ℓ)` fits on hierarchical topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerClass {
    /// Every other process (the paper's flat total exchange).
    All,
    /// Only peers on the same topology node (shared-memory links).
    Intra,
    /// Only peers on other nodes (wire links).
    Inter,
}

/// [`measure_exchange`] as one warm job on a shared pool.
pub fn measure_exchange_on(pool: &Pool, word_bytes: usize, h: usize, reps: u32) -> Result<f64> {
    measure_exchange_classed(pool, word_bytes, h, reps, PeerClass::All)
}

/// [`measure_exchange_on`] restricted to one [`PeerClass`]: the h words
/// split evenly over the eligible peers only (node membership read from
/// the fabric's topology view). With no eligible peer the exchange is
/// empty and the measurement reduces to the superstep fixed cost.
pub fn measure_exchange_classed(
    pool: &Pool,
    word_bytes: usize,
    h: usize,
    reps: u32,
    class: PeerClass,
) -> Result<f64> {
    let outs = pool.exec(
        move |ctx: &mut Context, _| -> Result<f64> {
            let p = ctx.p();
            let bytes = h * word_bytes;
            ctx.resize_memory_register(2)?;
            ctx.resize_message_queue(2 * (h + p as usize))?;
            ctx.sync(SYNC_DEFAULT)?;
            let src = ctx.register_global(bytes.max(1))?;
            let dst = ctx.register_global(bytes.max(1))?;
            ctx.sync(SYNC_DEFAULT)?;
            let q = ctx.topology().procs_per_node.max(1);
            // balanced exchange: my h words split evenly over the
            // eligible peers of the requested class
            let issue = move |ctx: &mut Context| -> Result<()> {
                let me = ctx.pid();
                let eligible: Vec<u32> = (0..p)
                    .filter(|&d| d != me)
                    .filter(|&d| match class {
                        PeerClass::All => true,
                        PeerClass::Intra => d / q == me / q,
                        PeerClass::Inter => d / q != me / q,
                    })
                    .collect();
                if eligible.is_empty() || h == 0 {
                    return Ok(());
                }
                let per_peer = h / eligible.len();
                let rem = h % eligible.len();
                let mut off = 0usize;
                for (k, &d) in eligible.iter().enumerate() {
                    let words = per_peer + usize::from(k < rem);
                    if words == 0 {
                        continue;
                    }
                    ctx.put(src, off, d, dst, off, words * word_bytes, MSG_DEFAULT)?;
                    off += words * word_bytes;
                }
                Ok(())
            };
            // warm + settle
            issue(ctx)?;
            ctx.sync(SYNC_DEFAULT)?;
            let sim_before = ctx.sim_time_ns();
            let wall = Instant::now();
            for _ in 0..reps {
                issue(ctx)?;
                ctx.sync(SYNC_DEFAULT)?;
            }
            let ns = match (sim_before, ctx.sim_time_ns()) {
                (Some(b), Some(a)) => (a - b) / reps as f64,
                _ => wall.elapsed().as_nanos() as f64 / reps as f64,
            };
            Ok(ns)
        },
        Args::none(),
    )?;
    let per_pid: Result<Vec<f64>> = outs.into_iter().collect();
    let per_pid = per_pid?;
    // BSP time of the h-relation = the slowest process
    Ok(per_pid.iter().copied().fold(0.0, f64::max))
}

/// Measured memcpy speed in ns/byte (Table 3's normaliser `r`).
pub fn measure_memcpy_r(bytes: usize, reps: u32) -> f64 {
    let src = vec![7u8; bytes];
    let mut dst = vec![0u8; bytes];
    // warm
    dst.copy_from_slice(&src);
    let t = Instant::now();
    for _ in 0..reps {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    }
    t.elapsed().as_nanos() as f64 / (reps as f64 * bytes as f64)
}

/// One Table-3 row: `(g, ℓ)` for a word size, with confidence intervals.
#[derive(Debug, Clone)]
pub struct ProbeRow {
    pub word_bytes: usize,
    pub g_ns: f64,
    pub g_ci: f64,
    pub l_ns: f64,
    pub l_ci: f64,
}

/// The paper's Table-3 fit for one word size and one peer class:
/// `g` from the asymptotic slope, `ℓ` from the small-h intercept,
/// `samples` independent estimates each.
fn fit_row(pool: &Pool, cfg: &ProbeConfig, w: usize, class: PeerClass) -> Result<ProbeRow> {
    let p = cfg.p;
    let n_max = (cfg.max_bytes / w).max(4 * p as usize);
    let mut gs = Vec::new();
    let mut ls = Vec::new();
    for _ in 0..cfg.samples {
        let t0 = measure_exchange_classed(pool, w, 0, cfg.reps, class)?;
        let tp = measure_exchange_classed(pool, w, p as usize, cfg.reps, class)?;
        let t2p = measure_exchange_classed(pool, w, 2 * p as usize, cfg.reps, class)?;
        let tmax = measure_exchange_classed(pool, w, n_max, cfg.reps, class)?;
        let g = (tmax - t2p) / (n_max - 2 * p as usize) as f64;
        let l = f64::max(t0, 2.0 * tp - t2p);
        gs.push(g.max(0.0));
        ls.push(l.max(0.0));
    }
    let gs = Samples::from(gs);
    let ls = Samples::from(ls);
    Ok(ProbeRow {
        word_bytes: w,
        g_ns: gs.mean(),
        g_ci: gs.ci95(),
        l_ns: ls.mean(),
        l_ci: ls.ci95(),
    })
}

/// Run the full offline probe for one platform; records the rows into
/// `table` (keyed by the backend name) and returns them with the measured
/// memcpy speed `r` (ns/byte).
pub fn run_offline_probe(
    platform: &Platform,
    cfg: &ProbeConfig,
    table: &Arc<ProbeTable>,
) -> Result<(Vec<ProbeRow>, f64)> {
    let backend = platform.make_fabric(1).name();
    let r = measure_memcpy_r(cfg.max_bytes.min(8 << 20), 5);
    let p = cfg.p;
    // One warm team serves the whole sweep (4 × samples × word-size jobs):
    // the measured intervals never include process spawn or fabric build.
    let pool = Pool::new(platform.clone(), p);
    let mut rows = Vec::new();
    for &w in &cfg.word_sizes {
        let row = fit_row(&pool, cfg, w, PeerClass::All)?;
        table.record(
            backend,
            p,
            BspParams { word_bytes: w, g_ns: row.g_ns, l_ns: row.l_ns },
            r,
        );
        rows.push(row);
    }
    Ok((rows, r))
}

/// Per-level `(g, ℓ)` fits for a hierarchical platform (tentpole: the
/// probe learns what each topology *level* costs, not one blended
/// number). Runs the Table-3 estimators twice with the exchange
/// restricted to [`PeerClass::Intra`] and [`PeerClass::Inter`] peers,
/// recording the fits under `"<backend>/intra"` and `"<backend>/inter"`.
/// On a flat (single-level) platform there is nothing to separate and
/// the result is empty.
pub fn run_level_probe(
    platform: &Platform,
    cfg: &ProbeConfig,
    table: &Arc<ProbeTable>,
) -> Result<Vec<(String, Vec<ProbeRow>)>> {
    let p = cfg.p;
    let fabric = platform.make_fabric(p);
    let topo = fabric.topology();
    if topo.levels < 2 || topo.procs_per_node < 2 {
        return Ok(Vec::new());
    }
    let backend = fabric.name();
    let r = measure_memcpy_r(cfg.max_bytes.min(8 << 20), 5);
    let pool = Pool::new(platform.clone(), p);
    let mut out = Vec::new();
    for (level, class) in [("intra", PeerClass::Intra), ("inter", PeerClass::Inter)] {
        let key = format!("{backend}/{level}");
        let mut rows = Vec::new();
        for &w in &cfg.word_sizes {
            let row = fit_row(&pool, cfg, w, class)?;
            table.record(
                &key,
                p,
                BspParams { word_bytes: w, g_ns: row.g_ns, l_ns: row.l_ns },
                r,
            );
            rows.push(row);
        }
        out.push((key, rows));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memcpy_r_is_sane() {
        let r = measure_memcpy_r(1 << 20, 3);
        assert!(r > 0.001 && r < 100.0, "r = {r} ns/byte");
    }

    #[test]
    fn exchange_time_grows_with_h() {
        // medians over several attempts: wall-clock on a single core that
        // is concurrently running the rest of the suite is noisy
        let plat = Platform::shared().checked(false);
        let med = |h: usize| {
            let mut v: Vec<f64> =
                (0..5).map(|_| measure_exchange(&plat, 2, 8, h, 2).unwrap()).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[2]
        };
        let t_small = med(16);
        let t_large = med(1 << 18);
        assert!(t_large > t_small, "{t_large} vs {t_small}");
    }

    #[test]
    fn sim_fabric_reports_sim_time() {
        let plat = Platform::rdma();
        let t = measure_exchange(&plat, 4, 8, 256, 1).unwrap();
        let t2 = measure_exchange(&plat, 4, 8, 256, 1).unwrap();
        assert!(t > 0.0);
        assert_eq!(t, t2, "netsim must be deterministic");
    }

    /// The per-level probe separates what the blended flat fit mixes:
    /// on the hybrid fabric intra-node links price at the shared-memory
    /// personality (expensive per byte, cheap latency) and inter-node
    /// at the wire personality — the simulated clock is deterministic,
    /// so the ordering of the fitted slopes is exact, not statistical.
    #[test]
    fn level_probe_fits_each_level() {
        let table = Arc::new(ProbeTable::default());
        let cfg = ProbeConfig {
            p: 4,
            word_sizes: vec![8],
            max_bytes: 1 << 16,
            reps: 1,
            samples: 1,
        };
        let levels = run_level_probe(&Platform::hybrid(2), &cfg, &table).unwrap();
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[0].0, "hybrid/intra");
        assert_eq!(levels[1].0, "hybrid/inter");
        let g_intra = levels[0].1[0].g_ns;
        let g_inter = levels[1].1[0].g_ns;
        assert!(g_intra > 0.0 && g_inter > 0.0, "{g_intra} / {g_inter}");
        // shm memcpy per byte (0.35 ns) > one wire hop (0.143 ns): the
        // intra slope must come out strictly steeper
        assert!(g_intra > g_inter, "intra {g_intra} vs inter {g_inter}");
        // both levels landed in the table under their own keys
        assert_eq!(table.lookup("hybrid/intra", 4).params.len(), 1);
        assert_eq!(table.lookup("hybrid/inter", 4).params.len(), 1);
        // a flat platform has no levels to separate
        assert!(run_level_probe(&Platform::rdma(), &cfg, &table).unwrap().is_empty());
    }

    #[test]
    fn offline_probe_fills_table() {
        let table = Arc::new(ProbeTable::default());
        let cfg = ProbeConfig {
            p: 2,
            word_sizes: vec![8, 1024],
            max_bytes: 1 << 16,
            reps: 1,
            samples: 2,
        };
        let (rows, r) =
            run_offline_probe(&Platform::shared().checked(false), &cfg, &table).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(r > 0.0);
        let m = table.lookup("shared", 2);
        assert_eq!(m.params.len(), 2);
        assert!(m.h_relation_ns(100, 8) > 0.0);
    }
}
