//! Destination-side write-conflict resolution (CRCW, paper §2.1 and §3).
//!
//! LPF allows multiple writes to the same memory; they are "resolved in some
//! sequential order akin to arbitrary-order CRCW PRAM". Reading *and*
//! writing the same memory in one superstep is illegal.
//!
//! Phase 2 of `lpf_sync` (paper §3) performs this resolution **at the
//! destination**, using a radix sort over incoming write descriptors
//! (Table 1), and — for distributed backends — informs the sources which
//! byte ranges can be sent "without overlap", so overwritten bytes never
//! travel the wire and the realised h-relation is the trimmed one.
//!
//! Determinism: the winning writer of an overlapped byte is the descriptor
//! with the highest `(src_pid, seq)` pair — a fixed sequential order, which
//! is one valid arbitrary-order CRCW resolution and keeps every backend
//! bit-identical to every other (asserted by cross-backend tests).

use crate::core::{Pid, SlotKind};
use crate::util::radix::radix_sort_idx_by_key;

/// One incoming write at a destination process, in destination coordinates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WriteDesc {
    /// Destination slot identity (kind + index); generation already checked.
    pub slot_kind: SlotKind,
    pub slot_index: u32,
    /// Destination byte offset.
    pub dst_off: usize,
    /// Byte length.
    pub len: usize,
    /// Issuing process (for puts: the source pid; for local gets: self).
    pub src_pid: Pid,
    /// Per-source queue sequence number: total order within a source.
    pub seq: u32,
    /// Opaque handle for the caller (e.g. index into a payload table).
    /// 64-bit: a destination aggregates descriptors from all `p` sources,
    /// so its table can exceed one source's 2^32 sequence space — a `u32`
    /// here would silently alias payloads (ISSUE 4 satellite).
    pub tag: u64,
}

impl WriteDesc {
    fn slot_key(&self) -> u64 {
        let kind_bit = match self.slot_kind {
            SlotKind::Local => 0u64,
            SlotKind::Global => 1u64,
        };
        (kind_bit << 32) | self.slot_index as u64
    }
    /// Total order deciding CRCW winners (higher wins).
    fn order_key(&self) -> u64 {
        ((self.src_pid as u64) << 32) | self.seq as u64
    }
}

/// A resolved, non-overlapping segment some descriptor won.
///
/// `src_delta` is the byte offset *within the original descriptor's payload*
/// where this segment starts, so sources can send exactly the winning bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WriteSeg {
    /// Index into the input descriptor slice.
    pub desc: usize,
    /// Destination byte offset of the segment.
    pub dst_off: usize,
    /// Segment length, > 0.
    pub len: usize,
    /// Offset of the segment within the descriptor's payload.
    pub src_delta: usize,
}

/// Reusable working memory for [`resolve_writes_into`]: the sync engine
/// threads one of these per process through every superstep so the
/// steady-state resolution allocates nothing.
#[derive(Debug, Default)]
pub struct ResolveScratch {
    order: Vec<u32>,
    sort_tmp: Vec<u32>,
    bounds: Vec<usize>,
    active: Vec<u32>,
}

/// Resolve write conflicts among `descs` (allocating convenience wrapper
/// around [`resolve_writes_into`]).
pub fn resolve_writes(descs: &[WriteDesc]) -> Vec<WriteSeg> {
    let mut segs = Vec::new();
    resolve_writes_into(descs, &mut ResolveScratch::default(), &mut segs);
    segs
}

/// Resolve write conflicts among `descs` into `segs`, reusing `sc`.
///
/// `segs` receives non-overlapping segments covering exactly the union of
/// all destination intervals, each byte assigned to its deterministic
/// winner. Runtime `O(m)` radix sort + `O(m·k)` sweep where `k` is the
/// maximum overlap depth (`k = 1` for conflict-free supersteps — the common
/// case — giving the paper's `O(m + h)` bound).
pub fn resolve_writes_into(descs: &[WriteDesc], sc: &mut ResolveScratch, segs: &mut Vec<WriteSeg>) {
    segs.clear();
    let ResolveScratch { order, sort_tmp, bounds, active } = sc;
    order.clear();
    order.extend((0..descs.len() as u32).filter(|&i| descs[i as usize].len > 0));
    // Sort by (slot, start offset) as two stable radix passes — least
    // significant key first. Packing both into one u64 would truncate the
    // slot key (the kind bit lives at bit 32), letting a Local and a Global
    // slot with equal low index bits interleave and split one slot's run,
    // which would skip conflict resolution between its descriptors.
    radix_sort_idx_by_key(order, sort_tmp, |i| descs[i as usize].dst_off as u64);
    radix_sort_idx_by_key(order, sort_tmp, |i| descs[i as usize].slot_key());

    let mut i = 0;
    while i < order.len() {
        let slot_key = descs[order[i] as usize].slot_key();
        // Gather the run of descriptors in this slot.
        let mut j = i;
        while j < order.len() && descs[order[j] as usize].slot_key() == slot_key {
            j += 1;
        }
        let run = &order[i..j];

        // Fast path: strictly non-overlapping run (common case).
        let mut overlap = false;
        for w in run.windows(2) {
            let a = &descs[w[0] as usize];
            let b = &descs[w[1] as usize];
            if a.dst_off + a.len > b.dst_off {
                overlap = true;
                break;
            }
        }
        if !overlap {
            for &d in run {
                let d = d as usize;
                segs.push(WriteSeg {
                    desc: d,
                    dst_off: descs[d].dst_off,
                    len: descs[d].len,
                    src_delta: 0,
                });
            }
            i = j;
            continue;
        }

        // Sweep over interval boundaries within the slot.
        bounds.clear();
        for &d in run {
            bounds.push(descs[d as usize].dst_off);
            bounds.push(descs[d as usize].dst_off + descs[d as usize].len);
        }
        bounds.sort_unstable();
        bounds.dedup();
        active.clear();
        let mut cursor = 0usize; // next index in `run` to activate
        for w in bounds.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            while cursor < run.len() && descs[run[cursor] as usize].dst_off <= lo {
                active.push(run[cursor]);
                cursor += 1;
            }
            active.retain(|&d| {
                let d = &descs[d as usize];
                d.dst_off + d.len > lo
            });
            // Winner: highest (src_pid, seq) covering [lo, hi).
            let winner = active
                .iter()
                .copied()
                .filter(|&d| {
                    let d = &descs[d as usize];
                    d.dst_off <= lo && d.dst_off + d.len >= hi
                })
                .max_by_key(|&d| descs[d as usize].order_key());
            if let Some(d) = winner {
                let d = d as usize;
                // Merge with previous segment when contiguous & same desc.
                if let Some(last) = segs.last_mut() {
                    if last.desc == d && last.dst_off + last.len == lo {
                        last.len += hi - lo;
                        continue;
                    }
                }
                segs.push(WriteSeg {
                    desc: d,
                    dst_off: lo,
                    len: hi - lo,
                    src_delta: lo - descs[d].dst_off,
                });
            }
        }
        i = j;
    }
}

/// A byte interval in a destination slot, for read/write legality checks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Interval {
    pub slot_kind: SlotKind,
    pub slot_index: u32,
    pub off: usize,
    pub len: usize,
}

impl Interval {
    fn slot_key(&self) -> u64 {
        let kind_bit = match self.slot_kind {
            SlotKind::Local => 0u64,
            SlotKind::Global => 1u64,
        };
        (kind_bit << 32) | self.slot_index as u64
    }
}

/// One endpoint event of the read/write legality sweep.
#[derive(Debug, Clone, Copy)]
struct Ev {
    key: u64,
    pos: usize,
    end: usize,
    is_read: bool,
    idx: usize,
}

/// Reusable event buffer for [`find_read_write_overlap_scratch`].
#[derive(Debug, Default)]
pub struct OverlapScratch {
    evs: Vec<Ev>,
}

/// Checked-mode legality (allocating convenience wrapper around
/// [`find_read_write_overlap_scratch`]).
pub fn find_read_write_overlap(reads: &[Interval], writes: &[Interval]) -> Option<(usize, usize)> {
    find_read_write_overlap_scratch(reads, writes, &mut OverlapScratch::default())
}

/// Checked-mode legality: detect any byte that is both read and written in
/// the same superstep on one process (illegal per paper §2.1). Returns the
/// indices of an offending `(read, write)` pair, if any. `O((n+m) log(n+m))`
/// time, no allocation once `sc` has grown.
///
/// Sweep: within each slot run (events sorted by start), an interval of one
/// polarity overlaps an earlier one of the other polarity iff the running
/// maximum end of the opposite polarity exceeds its start — complete for
/// pairwise overlap detection.
pub fn find_read_write_overlap_scratch(
    reads: &[Interval],
    writes: &[Interval],
    sc: &mut OverlapScratch,
) -> Option<(usize, usize)> {
    let evs = &mut sc.evs;
    evs.clear();
    for (idx, r) in reads.iter().enumerate().filter(|(_, r)| r.len > 0) {
        evs.push(Ev { key: r.slot_key(), pos: r.off, end: r.off + r.len, is_read: true, idx });
    }
    for (idx, w) in writes.iter().enumerate().filter(|(_, w)| w.len > 0) {
        evs.push(Ev { key: w.slot_key(), pos: w.off, end: w.off + w.len, is_read: false, idx });
    }
    evs.sort_unstable_by_key(|e| (e.key, e.pos));
    let mut i = 0;
    while i < evs.len() {
        let mut j = i;
        while j < evs.len() && evs[j].key == evs[i].key {
            j += 1;
        }
        let run = &evs[i..j];
        let mut max_read_end: Option<(usize, usize)> = None; // (end, idx)
        let mut max_write_end: Option<(usize, usize)> = None;
        for e in run {
            if e.is_read {
                if let Some((wend, widx)) = max_write_end {
                    if wend > e.pos {
                        return Some((e.idx, widx));
                    }
                }
                if max_read_end.map_or(true, |(end, _)| e.end > end) {
                    max_read_end = Some((e.end, e.idx));
                }
            } else {
                if let Some((rend, ridx)) = max_read_end {
                    if rend > e.pos {
                        return Some((ridx, e.idx));
                    }
                }
                if max_write_end.map_or(true, |(end, _)| e.end > end) {
                    max_write_end = Some((e.end, e.idx));
                }
            }
        }
        i = j;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wd(slot: u32, off: usize, len: usize, pid: Pid, seq: u32, tag: u64) -> WriteDesc {
        WriteDesc {
            slot_kind: SlotKind::Global,
            slot_index: slot,
            dst_off: off,
            len,
            src_pid: pid,
            seq,
            tag,
        }
    }

    /// Oracle: byte-by-byte sequential replay in (src_pid, seq) order.
    fn oracle(descs: &[WriteDesc], size: usize) -> Vec<Option<usize>> {
        let mut order: Vec<usize> = (0..descs.len()).collect();
        order.sort_by_key(|&i| ((descs[i].src_pid as u64) << 32) | descs[i].seq as u64);
        let mut owner = vec![None; size];
        for &i in &order {
            let d = &descs[i];
            for b in d.dst_off..d.dst_off + d.len {
                owner[b] = Some(i);
            }
        }
        owner
    }

    fn replay(descs: &[WriteDesc], segs: &[WriteSeg], size: usize) -> Vec<Option<usize>> {
        let mut owner = vec![None; size];
        for s in segs {
            for b in s.dst_off..s.dst_off + s.len {
                assert!(owner[b].is_none(), "segments must not overlap");
                owner[b] = Some(s.desc);
            }
        }
        let _ = descs;
        owner
    }

    #[test]
    fn disjoint_writes_pass_through() {
        let d = vec![wd(0, 0, 4, 0, 0, 0), wd(0, 8, 4, 1, 0, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(segs.len(), 2);
        assert_eq!(replay(&d, &segs, 16), oracle(&d, 16));
    }

    #[test]
    fn full_overlap_highest_pid_wins() {
        let d = vec![wd(0, 0, 8, 0, 0, 0), wd(0, 0, 8, 3, 0, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].desc, 1);
        assert_eq!(replay(&d, &segs, 8), oracle(&d, 8));
    }

    #[test]
    fn partial_overlap_trims_loser() {
        // [0,8) from pid 0; [4,12) from pid 1 → pid 0 keeps [0,4), pid 1 all.
        let d = vec![wd(0, 0, 8, 0, 0, 0), wd(0, 4, 8, 1, 0, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(replay(&d, &segs, 12), oracle(&d, 12));
        let total: usize = segs.iter().map(|s| s.len).sum();
        assert_eq!(total, 12, "trimmed h-relation sends exactly the union");
        // src_delta lets the source slice its payload
        let loser: Vec<_> = segs.iter().filter(|s| s.desc == 0).collect();
        assert_eq!(loser.len(), 1);
        assert_eq!(loser[0].src_delta, 0);
        assert_eq!(loser[0].len, 4);
    }

    #[test]
    fn same_pid_later_seq_wins() {
        let d = vec![wd(0, 0, 8, 2, 0, 0), wd(0, 2, 2, 2, 1, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(replay(&d, &segs, 8), oracle(&d, 8));
        // middle chunk belongs to seq 1; the winner's src_delta points into
        // the *winning* descriptor payload
        let mid = segs.iter().find(|s| s.dst_off == 2).unwrap();
        assert_eq!(mid.desc, 1);
        assert_eq!(mid.src_delta, 0);
    }

    #[test]
    fn nested_interval_splits_outer() {
        // outer [0,12) pid 0; inner [4,8) pid 5 → outer split into two segs.
        let d = vec![wd(0, 0, 12, 0, 0, 0), wd(0, 4, 4, 5, 0, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(replay(&d, &segs, 12), oracle(&d, 12));
        let outer: Vec<_> = segs.iter().filter(|s| s.desc == 0).collect();
        assert_eq!(outer.len(), 2);
        assert_eq!((outer[0].dst_off, outer[0].len, outer[0].src_delta), (0, 4, 0));
        assert_eq!((outer[1].dst_off, outer[1].len, outer[1].src_delta), (8, 4, 8));
    }

    #[test]
    fn different_slots_do_not_conflict() {
        let d = vec![wd(0, 0, 8, 0, 0, 0), wd(1, 0, 8, 1, 0, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(segs.len(), 2);
    }

    #[test]
    fn zero_length_descs_ignored() {
        let d = vec![wd(0, 0, 0, 0, 0, 0), wd(0, 0, 4, 1, 0, 1)];
        let segs = resolve_writes(&d);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].desc, 1);
    }

    #[test]
    fn randomised_against_oracle() {
        use crate::util::rng::XorShift64;
        let mut rng = XorShift64::new(0xC0FFEE);
        for case in 0..200 {
            let n = 1 + rng.below_usize(12);
            let size = 64;
            let descs: Vec<WriteDesc> = (0..n)
                .map(|i| {
                    let off = rng.below_usize(size - 1);
                    let len = 1 + rng.below_usize(size - off);
                    wd(rng.below(2) as u32, off, len, rng.below(4) as Pid, i as u32, i as u64)
                })
                .collect();
            let segs = resolve_writes(&descs);
            // replay per slot
            for slot in 0..2u32 {
                let dd: Vec<WriteDesc> =
                    descs.iter().filter(|d| d.slot_index == slot).cloned().collect();
                if dd.is_empty() {
                    continue;
                }
                let idx_map: Vec<usize> =
                    (0..descs.len()).filter(|&i| descs[i].slot_index == slot).collect();
                let segs_slot: Vec<WriteSeg> = segs
                    .iter()
                    .filter(|s| descs[s.desc].slot_index == slot)
                    .map(|s| WriteSeg {
                        desc: idx_map.iter().position(|&i| i == s.desc).unwrap(),
                        ..s.clone()
                    })
                    .collect();
                assert_eq!(
                    replay(&dd, &segs_slot, size),
                    oracle(&dd, size),
                    "case {case} slot {slot} mismatch"
                );
            }
        }
    }

    #[test]
    fn local_and_global_slots_with_same_index_do_not_interleave() {
        // Regression: the old single-u64 sort key truncated the slot-kind
        // bit, so a Local write whose offset fell between two overlapping
        // Global writes split the Global run and skipped their resolution.
        let mk = |kind: SlotKind, off: usize, len: usize, pid: Pid, seq: u32, tag: u64| WriteDesc {
            slot_kind: kind,
            slot_index: 0,
            dst_off: off,
            len,
            src_pid: pid,
            seq,
            tag,
        };
        let d = vec![
            mk(SlotKind::Global, 0, 32, 0, 0, 0),
            mk(SlotKind::Local, 8, 4, 1, 0, 1),
            mk(SlotKind::Global, 16, 4, 2, 0, 2),
        ];
        let segs = resolve_writes(&d);
        for (a_i, a) in segs.iter().enumerate() {
            for b in &segs[a_i + 1..] {
                if d[a.desc].slot_kind == d[b.desc].slot_kind {
                    assert!(
                        a.dst_off + a.len <= b.dst_off || b.dst_off + b.len <= a.dst_off,
                        "overlapping segments {a:?} / {b:?}"
                    );
                }
            }
        }
        // the overlap [16,20) goes to the higher (pid, seq) writer
        let winner = segs
            .iter()
            .find(|s| s.dst_off == 16 && d[s.desc].slot_kind == SlotKind::Global)
            .unwrap();
        assert_eq!(d[winner.desc].src_pid, 2);
    }

    #[test]
    fn scratch_reuse_is_equivalent_to_fresh() {
        use crate::util::rng::XorShift64;
        let mut rng = XorShift64::new(0xAB);
        let mut sc = ResolveScratch::default();
        let mut segs = Vec::new();
        let mut ov = OverlapScratch::default();
        for _ in 0..50 {
            let n = 1 + rng.below_usize(10);
            let descs: Vec<WriteDesc> = (0..n)
                .map(|i| {
                    let off = rng.below_usize(31);
                    let len = 1 + rng.below_usize(32 - off);
                    wd(rng.below(2) as u32, off, len, rng.below(4) as Pid, i as u32, i as u64)
                })
                .collect();
            resolve_writes_into(&descs, &mut sc, &mut segs);
            assert_eq!(segs, resolve_writes(&descs), "reused scratch must not change results");
            let iv = |off: usize| Interval {
                slot_kind: SlotKind::Global,
                slot_index: 0,
                off,
                len: 8,
            };
            let reads = vec![iv(rng.below_usize(16))];
            let writes = vec![iv(rng.below_usize(16))];
            assert_eq!(
                find_read_write_overlap_scratch(&reads, &writes, &mut ov).is_some(),
                find_read_write_overlap(&reads, &writes).is_some(),
            );
        }
    }

    #[test]
    fn tags_beyond_the_u32_boundary_stay_distinct() {
        // Regression (ISSUE 4 satellite): `tag` was u32, so a destination
        // table past 2^32 entries aliased payload indices. Descriptors
        // whose tags straddle the boundary must survive resolution with
        // their identities intact (pre-fix this did not even typecheck).
        let hi = u32::MAX as u64;
        let d = vec![
            wd(0, 0, 4, 0, 0, hi),
            wd(0, 8, 4, 1, 0, hi + 1),
            wd(0, 16, 4, 2, 0, hi + 2),
        ];
        let segs = resolve_writes(&d);
        let mut tags: Vec<u64> = segs.iter().map(|s| d[s.desc].tag).collect();
        tags.sort_unstable();
        assert_eq!(tags, vec![hi, hi + 1, hi + 2]);
    }

    #[test]
    fn read_write_overlap_detected() {
        let reads = vec![Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 0, len: 8 }];
        let writes = vec![Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 4, len: 2 }];
        assert_eq!(find_read_write_overlap(&reads, &writes), Some((0, 0)));
    }

    #[test]
    fn read_write_disjoint_ok() {
        let reads = vec![Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 0, len: 4 }];
        let writes = vec![Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 4, len: 4 }];
        assert_eq!(find_read_write_overlap(&reads, &writes), None);
    }

    #[test]
    fn read_write_different_slots_ok() {
        let reads = vec![Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 0, len: 8 }];
        let writes = vec![Interval { slot_kind: SlotKind::Global, slot_index: 1, off: 0, len: 8 }];
        assert_eq!(find_read_write_overlap(&reads, &writes), None);
    }

    #[test]
    fn hidden_overlap_behind_same_polarity_found() {
        // read [0,16); read [1,2); write [8,9) — fast windows(2) scan would
        // only compare neighbours; second pass must still find it.
        let reads = vec![
            Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 0, len: 16 },
            Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 1, len: 1 },
        ];
        let writes = vec![Interval { slot_kind: SlotKind::Global, slot_index: 0, off: 8, len: 1 }];
        assert!(find_read_write_overlap(&reads, &writes).is_some());
    }
}
