//! The shared 4-phase `lpf_sync` engine (paper §3).
//!
//! All four backends implement the *same* superstep strategy:
//!
//! 1. barrier + first meta-data exchange (tell destinations what arrives);
//! 2. destination-side CRCW conflict resolution (+ trim);
//! 3. the data exchange proper;
//! 4. final barrier.
//!
//! The seed re-implemented that pipeline once per fabric, with per-superstep
//! `Vec` churn and p² mutexed mailboxes — exactly the per-message software
//! overhead pMR-style measurements show dominating small-message
//! performance. This module factors the pipeline out once, running on the
//! per-process reusable arenas of [`crate::fabric::plan`]:
//!
//! * **phase 0** (engine): drain the request queue into the outbox arenas,
//!   coalescing queue-adjacent contiguous requests so descriptor counts
//!   track h-relations, not call counts;
//! * **phase 1** ([`Exchange::exchange_meta`], backend): move descriptors to
//!   their destinations — shared-memory outbox reads vs. simulated-NIC
//!   posts, direct all-to-all vs. randomised Bruck;
//! * **phase 2** (engine): build the destination-side write-descriptor
//!   table, verify read/write legality in checked mode, resolve CRCW
//!   conflicts with reusable scratch;
//! * **phase 3** ([`Exchange::exchange_data_begin`] +
//!   [`Exchange::exchange_data_end`], backend): move the winning bytes —
//!   destination-side memcpy (shared) vs. trim-notice round trip + source
//!   push + receiver matching (distributed);
//! * **phase 4** ([`Exchange::finish`], backend): the final barrier; the
//!   engine then accounts uniform [`SyncStats`] for every backend.
//!
//! **Split-phase supersteps.** Phase 3 is split at the point where every
//! winning byte has been *launched* but not yet *delivered*:
//! [`SyncEngine::sync_begin`] runs phases 0–2 plus the launch half and
//! returns control to the caller, [`SyncEngine::sync_end`] completes
//! delivery and the final barrier. Compute performed between the two
//! overlaps the in-flight exchange; the engine credits
//! `min(compute window, in-flight cost)` to
//! [`SyncDiagnostics::overlap_ns`](crate::fabric::SyncDiagnostics::overlap_ns). The
//! monolithic [`SyncEngine::superstep`] is literally `sync_begin` followed
//! by `sync_end`, so the bulk and split paths cannot drift apart: same
//! phases, same barriers, same accounting. Between begin and end the
//! caller must leave registered slots quiescent (see
//! `docs/sync-engine.md`); misuse (begin-while-begun, end-without-begin)
//! is a purely local `Illegal` raised before any barrier, so it can never
//! deadlock peers.
//!
//! In the steady state (capacities warmed up) a superstep performs **zero
//! heap allocations** on the shared backend — `bench_sync --smoke` asserts
//! this with a counting global allocator.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::core::{LpfError, Pid, Result, SyncAttr};
use crate::fabric::plan::{fill_outbox, OutTables, Scratch, SplitState, SyncPlan};
use crate::fabric::{ProtocolTier, SyncStats};
use crate::memory::SharedRegister;
use crate::netsim::faults::FaultPlan;
use crate::queue::Request;
use crate::sync::conflict::{
    find_read_write_overlap_scratch, resolve_writes_into, Interval, WriteDesc, WriteSeg,
};

/// What genuinely differs between backends. Implemented by the in-crate
/// fabrics; the engine drives one superstep through these hooks.
pub trait Exchange: Send + Sync {
    /// Per-superstep read/write legality verification on/off.
    fn checked(&self) -> bool;

    /// Protocol tier for one coalesced descriptor of `len` payload bytes
    /// from `src` to `dst`, decided at queue-drain time (phase 0). The
    /// engine stamps the result on the descriptor before it is published;
    /// backends that price tiers distinctly override this with their
    /// configured [`ProtocolConfig`](crate::fabric::ProtocolConfig). The
    /// default — everything rendezvous — is the pre-tier behaviour and
    /// remains correct for any backend.
    fn tier_for(&self, _src: Pid, _dst: Pid, _len: usize) -> ProtocolTier {
        ProtocolTier::Rendezvous
    }

    /// Phase 1: the first meta-data exchange, *including* the barrier after
    /// which every process's outbox is published.
    ///
    /// Contract on return: `s.incoming_puts` holds every put addressed to
    /// `pid` sorted by `(src_pid, seq)` — the canonical CRCW order — and
    /// `s.serve_gets` every get that reads `pid`'s memory, sorted by
    /// `(requester, seq)`.
    fn exchange_meta(&self, pid: Pid, engine: &SyncEngine, s: &mut Scratch) -> Result<()>;

    /// Phase 3a: *launch* the data exchange for the winning bytes of
    /// `s.segs` (descriptors in `s.descs`, payload sources in
    /// `s.incoming_puts` / `s.my_gets`) and return while delivery is in
    /// flight. Returns the simulated cost in ns of the in-flight remainder
    /// — the budget the engine's overlap credit is measured against. The
    /// default is a no-op returning 0: correct for any backend whose data
    /// phase runs entirely inside [`exchange_data_end`]
    /// (shared memory's destination-side memcpy cannot be launched early).
    ///
    /// [`exchange_data_end`]: Exchange::exchange_data_end
    fn exchange_data_begin(
        &self,
        _pid: Pid,
        _engine: &SyncEngine,
        _s: &mut Scratch,
    ) -> Result<u64> {
        Ok(0)
    }

    /// Phase 3b: complete delivery of the winning bytes into `pid`'s
    /// memory. Returns the payload bytes written. On error the engine
    /// aborts the context and propagates.
    fn exchange_data_end(&self, pid: Pid, engine: &SyncEngine, s: &mut Scratch) -> Result<u64>;

    /// Phase 4: the final barrier — the h-relation involving `pid` is
    /// complete when it returns.
    fn finish(&self, pid: Pid) -> Result<()>;

    /// Mark the context aborted so peers fail at their next collective
    /// instead of deadlocking (paper §2.1).
    fn abort_peers(&self, pid: Pid);
}

/// The backend-independent state of one context's sync pipeline: slot
/// registers and one [`SyncPlan`] arena per process.
pub struct SyncEngine {
    p: Pid,
    regs: Vec<Arc<SharedRegister>>,
    plans: Vec<SyncPlan>,
    /// Request coalescing at queue-drain time (on by default; `bench_sync`
    /// flips it off for the ablation).
    coalesce: AtomicBool,
    /// Installed fault-injection plan (None in production). Consulted at
    /// superstep entry here; backends and the registration path consult
    /// it through [`SyncEngine::fault_plan`].
    faults: RwLock<Option<Arc<FaultPlan>>>,
    /// Fast-path mirror of `faults.is_some()`: the per-superstep consult
    /// is a single relaxed read of an immutable-in-production flag, so
    /// the hot path never touches the lock word when no plan is
    /// installed (no cross-core RMW traffic on the ℓ-critical path).
    faults_installed: AtomicBool,
}

impl SyncEngine {
    /// Engine for `p` processes.
    pub fn new(p: Pid) -> Self {
        assert!(p > 0, "a context needs at least one process");
        SyncEngine {
            p,
            regs: (0..p).map(|_| SharedRegister::new()).collect(),
            plans: (0..p).map(|_| SyncPlan::new(p)).collect(),
            coalesce: AtomicBool::new(true),
            faults: RwLock::new(None),
            faults_installed: AtomicBool::new(false),
        }
    }

    /// Number of processes.
    pub fn p(&self) -> Pid {
        self.p
    }

    /// The slot register of process `pid`.
    pub fn register_of(&self, pid: Pid) -> &Arc<SharedRegister> {
        &self.regs[pid as usize]
    }

    /// Process `pid`'s outbox (readable by peers between the meta barrier
    /// and the final barrier — see [`crate::fabric::plan`]).
    pub fn outbox(&self, pid: Pid) -> &RwLock<OutTables> {
        &self.plans[pid as usize].outbox
    }

    /// Per-process transport statistics.
    pub fn stats(&self, pid: Pid) -> SyncStats {
        *self.plans[pid as usize].stats.lock().expect("stats poisoned")
    }

    /// Toggle request coalescing (ablation hook).
    pub fn set_coalescing(&self, on: bool) {
        self.coalesce.store(on, Ordering::Relaxed);
    }

    /// Whether request coalescing is active.
    pub fn coalescing(&self) -> bool {
        self.coalesce.load(Ordering::Relaxed)
    }

    /// Install (or clear) the fault-injection plan this engine and its
    /// backend consult (`None` = no faults; the production default).
    /// Call between jobs, not mid-superstep.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        let mut guard = self.faults.write().expect("fault plan poisoned");
        self.faults_installed.store(plan.is_some(), Ordering::Release);
        *guard = plan;
    }

    /// The installed fault-injection plan, if any. Without a plan this is
    /// one relaxed flag read; with one, an `Arc` clone — either way no
    /// heap allocation, so the zero-allocation steady state holds.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        if !self.faults_installed.load(Ordering::Acquire) {
            return None;
        }
        self.faults.read().expect("fault plan poisoned").clone()
    }

    /// Job-boundary reset (the pool's warm path): restore the state a
    /// freshly built engine would present — empty registers at default
    /// capacity, zeroed statistics, coalescing back to its default —
    /// while retaining every arena allocation and the slot-generation
    /// counters (stale handles from the previous job must fail, not alias;
    /// see [`crate::memory::Register::reset_for_job`]). Caller guarantees
    /// no process is inside a superstep.
    pub fn reset_for_job(&self) {
        for reg in &self.regs {
            reg.with_mut(|r| r.reset_for_job());
        }
        for plan in &self.plans {
            plan.reset_for_job();
        }
        self.coalesce.store(true, Ordering::Relaxed);
        // The fault plan stays installed across warm jobs (faults target
        // per-job trigger points); only its per-job counters restart.
        if let Some(faults) = self.fault_plan() {
            faults.reset_for_job();
        }
    }

    /// Run one superstep of the 4-phase strategy for `pid` over `ex`.
    ///
    /// Exactly [`sync_begin`](SyncEngine::sync_begin) followed by
    /// [`sync_end`](SyncEngine::sync_end) with an empty compute window:
    /// the bulk and split-phase paths share every phase, barrier, and
    /// counter by construction.
    pub fn superstep<E: Exchange>(
        &self,
        ex: &E,
        pid: Pid,
        reqs: &[Request],
        attr: SyncAttr,
    ) -> Result<()> {
        self.sync_begin(ex, pid, reqs, attr)?;
        self.sync_end(ex, pid)
    }

    /// First half of a split-phase superstep: phases 0–2 (outbox fill, meta
    /// exchange, conflict resolution) plus the launch half of the data
    /// exchange. On return the exchange is in flight and the caller may
    /// compute, provided it leaves registered slots quiescent; it must then
    /// call [`sync_end`](SyncEngine::sync_end). Calling `sync_begin` again
    /// first is a purely local `Illegal` (raised before any barrier, so it
    /// cannot deadlock peers).
    pub fn sync_begin<E: Exchange>(
        &self,
        ex: &E,
        pid: Pid,
        reqs: &[Request],
        attr: SyncAttr,
    ) -> Result<()> {
        let plan = &self.plans[pid as usize];

        // ---- fault injection (adversarial testing only; `None` in
        // production). A scheduled mid-job abort fires here, at superstep
        // entry and before any barrier: peers are aborted immediately (so
        // they observe PeerAborted at their next collective instead of
        // hanging) and the error is latched to surface from `sync_end` —
        // the split superstep's single completion point.
        let mut injected: Option<LpfError> = None;
        if let Some(faults) = self.fault_plan() {
            let step = plan.stats.lock().expect("stats poisoned").syncs;
            if let Some(e) = faults.abort_injection(pid, step) {
                ex.abort_peers(pid);
                injected = Some(e);
            }
        }

        let mut guard = plan.scratch.lock().expect("scratch poisoned");
        let s = &mut *guard;

        // ---- misuse: begin while a split superstep is in flight. Purely
        // local (no barrier has been entered for the new superstep), so
        // peers are unaffected and the caller can recover.
        if s.split.is_some() {
            return Err(LpfError::Illegal(
                "sync_begin while a split-phase superstep is already in flight".into(),
            ));
        }

        if let Some(e) = injected {
            // Peers are already aborting; run no phase, park the error for
            // sync_end so begin/end stay paired from the caller's view.
            s.split = Some(SplitState {
                sent: 0,
                desc_bytes: 0,
                seg_bytes: 0,
                began_at: Instant::now(),
                inflight_ns: 0,
                pending_err: Some(e),
                eager_msgs: 0,
                eager_bytes: 0,
                rdv_handshakes: 0,
            });
            return Ok(());
        }

        // ---- phase 0: coalesce + group the drained queue into the outbox.
        // A validation failure here happens before any barrier: abort so
        // peers observe PeerAborted instead of hanging at the meta barrier
        // (matters for direct Fabric users; Context pre-validates pids).
        let tier_for = |dst: Pid, len: usize| ex.tier_for(pid, dst, len);
        let sent = match fill_outbox(self.p, pid, reqs, self.coalescing(), &tier_for, s, &plan.outbox)
        {
            Ok(n) => n,
            Err(e) => {
                ex.abort_peers(pid);
                return Err(e);
            }
        };

        // ---- phase 1: first meta-data exchange (backend).
        ex.exchange_meta(pid, self, s)?;

        // ---- phase 2: destination-side write-descriptor table.
        {
            let Scratch { descs, incoming_puts, my_gets, put_count, .. } = s;
            descs.clear();
            *put_count = incoming_puts.len();
            for (i, m) in incoming_puts.iter().enumerate() {
                descs.push(WriteDesc {
                    slot_kind: m.dst_slot.kind(),
                    slot_index: m.dst_slot.index(),
                    dst_off: m.dst_off,
                    len: m.len,
                    src_pid: m.src_pid,
                    seq: m.seq,
                    tag: i as u64,
                });
            }
            for (i, g) in my_gets.iter().enumerate() {
                descs.push(WriteDesc {
                    slot_kind: g.dst_slot.kind(),
                    slot_index: g.dst_slot.index(),
                    dst_off: g.dst_off,
                    len: g.len,
                    src_pid: pid,
                    seq: g.seq,
                    tag: (*put_count + i) as u64,
                });
            }
        }

        // ---- checked mode: read/write legality on MY memory. Reads are my
        // puts' sources plus the gets I serve; writes the incoming table.
        if ex.checked() {
            let Scratch { reads, writes, cputs, serve_gets, descs, overlap, .. } = s;
            reads.clear();
            writes.clear();
            for m in cputs.iter() {
                reads.push(Interval {
                    slot_kind: m.src_slot.kind(),
                    slot_index: m.src_slot.index(),
                    off: m.src_off,
                    len: m.len,
                });
            }
            for g in serve_gets.iter() {
                reads.push(Interval {
                    slot_kind: g.src_slot.kind(),
                    slot_index: g.src_slot.index(),
                    off: g.src_off,
                    len: g.len,
                });
            }
            for d in descs.iter() {
                writes.push(Interval {
                    slot_kind: d.slot_kind,
                    slot_index: d.slot_index,
                    off: d.dst_off,
                    len: d.len,
                });
            }
            if find_read_write_overlap_scratch(reads, writes, overlap).is_some() {
                ex.abort_peers(pid);
                return Err(LpfError::Illegal(
                    "read and write of the same memory in one superstep".into(),
                ));
            }
        }

        // ---- CRCW conflict resolution (or the vouched-disjoint fast path).
        let (desc_bytes, seg_bytes);
        {
            let Scratch { descs, segs, resolve, .. } = s;
            if attr.assume_no_conflicts {
                segs.clear();
                segs.extend(descs.iter().enumerate().filter(|(_, d)| d.len > 0).map(
                    |(i, d)| WriteSeg { desc: i, dst_off: d.dst_off, len: d.len, src_delta: 0 },
                ));
            } else {
                resolve_writes_into(descs, resolve, segs);
            }
            desc_bytes = descs.iter().map(|d| d.len as u64).sum::<u64>();
            seg_bytes = segs.iter().map(|g| g.len as u64).sum::<u64>();
        }

        // ---- phase 3a: launch the data exchange (backend); its simulated
        // in-flight cost is the budget the overlap credit is capped by.
        let inflight_ns = match ex.exchange_data_begin(pid, self, s) {
            Ok(ns) => ns,
            Err(e) => {
                ex.abort_peers(pid);
                return Err(e);
            }
        };

        s.split = Some(SplitState {
            sent,
            desc_bytes,
            seg_bytes,
            began_at: Instant::now(),
            inflight_ns,
            pending_err: None,
            eager_msgs: s.tier_eager_msgs,
            eager_bytes: s.tier_eager_bytes,
            rdv_handshakes: s.tier_rdv_msgs,
        });
        Ok(())
    }

    /// Second half of a split-phase superstep: complete delivery of the
    /// in-flight bytes, account statistics (including the overlap credit),
    /// and run the final barrier. Returns a purely local `Illegal` if no
    /// split superstep is in flight.
    pub fn sync_end<E: Exchange>(&self, ex: &E, pid: Pid) -> Result<()> {
        let plan = &self.plans[pid as usize];
        let mut guard = plan.scratch.lock().expect("scratch poisoned");
        let s = &mut *guard;

        let Some(split) = s.split.take() else {
            return Err(LpfError::Illegal("sync_end without a matching sync_begin".into()));
        };

        // An error latched at sync_begin (injected abort): peers were
        // aborted there; this is where it surfaces, on every backend.
        if let Some(e) = split.pending_err {
            return Err(e);
        }

        // The compute window closes now; measure it before delivery work.
        let compute_ns = u64::try_from(split.began_at.elapsed().as_nanos()).unwrap_or(u64::MAX);

        // ---- phase 3b: complete delivery (backend).
        let bytes_in = match ex.exchange_data_end(pid, self, s) {
            Ok(b) => b,
            Err(e) => {
                ex.abort_peers(pid);
                return Err(e);
            }
        };

        // bytes_out is attributed at the destination, where the post-trim
        // winners are known: puts to their source, gets to their server.
        // This happens *before* the final barrier so that every process's
        // stats are fully settled by the time its own sync() returns.
        {
            let Scratch { segs, descs, incoming_puts, my_gets, put_count, bytes_out_by_src, .. } =
                s;
            bytes_out_by_src.clear();
            bytes_out_by_src.resize(self.p as usize, 0);
            for seg in segs.iter() {
                let d = &descs[seg.desc];
                let src = if (d.tag as usize) < *put_count {
                    incoming_puts[d.tag as usize].src_pid
                } else {
                    my_gets[d.tag as usize - *put_count].server
                };
                bytes_out_by_src[src as usize] += seg.len as u64;
            }
            for (src, &b) in bytes_out_by_src.iter().enumerate() {
                if b > 0 {
                    self.plans[src].stats.lock().expect("stats poisoned").bytes_out += b;
                }
            }
        }

        // ---- uniform statistics (identical accounting on every backend).
        // Also pre-barrier: once any process returns from sync(), every
        // process's counters for this superstep are settled. (On a failed
        // final barrier the counters still include this superstep — the
        // context is fatally dead at that point anyway.)
        {
            let mut st = plan.stats.lock().expect("stats poisoned");
            st.syncs += 1;
            st.bytes_in += bytes_in;
            st.msgs_out += split.sent as u64;
            st.bytes_trimmed += split.desc_bytes - split.seg_bytes;
            // Overlap credit: communication cost genuinely hidden behind
            // the caller's compute window. Capped by the in-flight cost so
            // a long compute window never inflates it, and ~0 on the bulk
            // path (empty window). Wall-clock-derived, hence diagnostic
            // (excluded from SyncStats equality).
            st.diag.overlap_ns += compute_ns.min(split.inflight_ns);
            // Tier accounting is uniform and engine-side: outgoing
            // coalesced descriptors tallied at classification (phase 0),
            // so every backend reports identical counters for identical
            // workloads. A rendezvous-classified descriptor costs exactly
            // one handshake (trim notice for a put, get-request for a get).
            st.diag.eager_msgs += split.eager_msgs;
            st.diag.eager_bytes += split.eager_bytes;
            st.diag.rendezvous_handshakes += split.rdv_handshakes;
            // Registration-cache counters are cumulative over the scratch
            // lifetime (a job); mirror, don't accumulate.
            st.diag.reg_cache_hits = s.reg_cache.hits();
            st.diag.reg_cache_misses = s.reg_cache.misses();
        }

        // ---- phase 4: final barrier.
        ex.finish(pid)
    }
}
