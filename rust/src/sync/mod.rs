//! The `lpf_sync` engine building blocks shared by all fabrics.
pub mod conflict;
pub mod metadata;
