//! The `lpf_sync` engine shared by all fabrics: the 4-phase superstep
//! pipeline ([`engine`]), destination-side CRCW conflict resolution
//! ([`conflict`]), and the meta-data exchange schedules ([`metadata`]).
pub mod conflict;
pub mod engine;
pub mod metadata;
