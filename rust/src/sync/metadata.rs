//! Meta-data total exchanges (paper §3.1).
//!
//! Every `lpf_sync` performs an all-to-all of message descriptors. Two
//! algorithms, as in the paper:
//!
//! * **direct** — every process sends to every destination it has items
//!   for: up to `p − 1` messages per process, minimal payload. Best
//!   throughput, `O(p)` latency term.
//! * **randomised Bruck (RB)** — the Bruck index algorithm combined with
//!   Valiant two-phase randomised routing: `2⌈log₂ p⌉` messages per process
//!   w.h.p., payload inflated by `O(log p)`. Best latency on high-latency
//!   fabrics.
//!
//! The functions here compute the *forwarding schedule*; fabrics move the
//! actual items through their wire and account costs per hop.

use crate::util::rng::XorShift64;

/// Number of Bruck rounds for `p` processes: ⌈log₂ p⌉.
pub fn bruck_rounds(p: u32) -> u32 {
    if p <= 1 {
        0
    } else {
        32 - (p - 1).leading_zeros()
    }
}

/// Bruck forwarding rule: in round `r`, the current `owner` forwards an
/// item ultimately destined for `dst` to `(owner + 2^r) mod p` iff bit `r`
/// of the remaining relative distance `(dst − owner) mod p` is set.
/// Returns the next owner, or `None` if the item stays put this round.
pub fn bruck_forward(p: u32, owner: u32, dst: u32, round: u32) -> Option<u32> {
    let rel = (dst + p - owner) % p;
    if rel & (1 << round) != 0 {
        Some((owner + (1 << round)) % p)
    } else {
        None
    }
}

/// Valiant two-phase routing: pick a uniformly random intermediate for an
/// item; phase 1 routes to the intermediate, phase 2 to the destination.
/// Randomisation destroys adversarial patterns (e.g. all-to-one) w.h.p.
pub fn valiant_intermediate(rng: &mut XorShift64, p: u32) -> u32 {
    rng.below(p as u64) as u32
}

/// Simulate the full Bruck delivery of one item: the sequence of owners it
/// passes through from `src` to `dst` (for tests and cost accounting).
pub fn bruck_path(p: u32, src: u32, dst: u32) -> Vec<u32> {
    let mut path = vec![src];
    let mut owner = src;
    for r in 0..bruck_rounds(p) {
        if let Some(next) = bruck_forward(p, owner, dst, r) {
            owner = next;
            path.push(owner);
        }
    }
    debug_assert_eq!(owner, dst);
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_are_ceil_log2() {
        assert_eq!(bruck_rounds(1), 0);
        assert_eq!(bruck_rounds(2), 1);
        assert_eq!(bruck_rounds(3), 2);
        assert_eq!(bruck_rounds(4), 2);
        assert_eq!(bruck_rounds(5), 3);
        assert_eq!(bruck_rounds(8), 3);
        assert_eq!(bruck_rounds(9), 4);
    }

    #[test]
    fn every_item_reaches_destination() {
        for p in [1u32, 2, 3, 4, 5, 7, 8, 12, 16, 33] {
            for src in 0..p {
                for dst in 0..p {
                    let path = bruck_path(p, src, dst);
                    assert_eq!(*path.last().unwrap(), dst, "p={p} {src}→{dst}");
                    assert!(
                        path.len() as u32 <= bruck_rounds(p) + 1,
                        "path length within log bound"
                    );
                }
            }
        }
    }

    #[test]
    fn each_process_sends_to_one_partner_per_round() {
        // In round r every process sends only to (pid + 2^r) mod p — the
        // property that bounds messages per process at log p.
        let p = 8;
        for r in 0..bruck_rounds(p) {
            for owner in 0..p {
                for dst in 0..p {
                    if let Some(next) = bruck_forward(p, owner, dst, r) {
                        assert_eq!(next, (owner + (1 << r)) % p);
                    }
                }
            }
        }
    }

    #[test]
    fn valiant_intermediates_cover_range() {
        let mut rng = XorShift64::new(7);
        let p = 8;
        let mut seen = vec![false; p as usize];
        for _ in 0..1000 {
            seen[valiant_intermediate(&mut rng, p) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all intermediates used");
    }
}
