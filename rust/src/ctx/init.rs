//! `lpf_hook` and the `lpf_init_t` rendezvous — LPF's interoperability
//! mechanism (paper §2.3, Algorithm 3, and the Spark integration of §4.3).
//!
//! The paper's distributed implementations create an `lpf_init_t` over
//! TCP/IP: every process calls `lpf_mpi_initialize_over_tcp(hostname, port,
//! timeout, pid, nprocs, &init)` where one peer is the master, then calls
//! `lpf_hook(init, spmd, args)` any number of times. We reproduce this
//! 1:1 for threads of arbitrary host frameworks (sparksim workers in the
//! Table-4 experiment): the "master hostname:port" string keys a global
//! rendezvous; `pid`/`nprocs` are supplied by the host framework exactly as
//! Spark workers derive them from a broadcast hostname array.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::{run_spmd, Context, ContextGroup, Platform};
use crate::core::{Args, LpfError, Pid, Result};
use crate::netsim::faults::FaultPlan;

/// Shared rendezvous state for one master address.
struct Rendezvous {
    nprocs: Pid,
    platform: Platform,
    state: Mutex<RendezvousState>,
    cv: Condvar,
}

#[derive(Default)]
struct RendezvousState {
    /// Group for each hook epoch; entries retired once all procs leave.
    groups: HashMap<u64, (Arc<ContextGroup>, Pid)>,
    /// Processes that ever arrived (monotonic — a fast peer finalising
    /// must not make a slow peer miss the rendezvous).
    arrived: Pid,
    /// Processes currently holding the init (registry cleanup).
    registered: Pid,
    /// Epochs fully finished (all peers left). Epoch `e` may only start
    /// once `completed == e`, which is what makes the warm team reusable:
    /// hook epochs on one master are serialised, exactly like the jobs of a
    /// [`crate::pool::Pool`].
    completed: u64,
    /// How many peers have finished each in-flight epoch.
    finishers: HashMap<u64, Pid>,
    /// The warm team from the last completed epoch (already reset), if it
    /// ended healthy — the `lpf_hook`-over-a-live-pool path: repeated hooks
    /// from a host framework (the sparksim Table-4 bootstrap) reuse the
    /// fabric, arenas, and tuned barrier instead of rebuilding them.
    warm: Option<Arc<ContextGroup>>,
    /// Fault-injection plan every hook epoch installs on its team (warm
    /// or freshly built) — the hook-epoch analogue of
    /// [`crate::pool::Pool::set_fault_plan`].
    fault_plan: Option<Arc<FaultPlan>>,
}

/// `lpf_init_t`: one process's handle for hooking into a context shared
/// with `nprocs − 1` peers. Not `Send`: like the paper's object it belongs
/// to the process that created it.
pub struct Init {
    rendezvous: Arc<Rendezvous>,
    pid: Pid,
    nprocs: Pid,
    epoch: AtomicU32,
    finalized: bool,
}

fn registry() -> &'static Mutex<HashMap<String, Arc<Rendezvous>>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, Arc<Rendezvous>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

impl Init {
    /// The analogue of `lpf_mpi_initialize_over_tcp`: rendezvous `nprocs`
    /// processes on the master address `master` (any unique string — the
    /// paper uses `hostname:port`). Blocks until all peers arrived or
    /// `timeout` elapses. `platform` must agree across peers (the first
    /// arrival's platform wins; mismatches are reported).
    pub fn over_master(
        master: &str,
        pid: Pid,
        nprocs: Pid,
        timeout: Duration,
        platform: Platform,
    ) -> Result<Init> {
        if nprocs == 0 || pid >= nprocs {
            return Err(LpfError::Illegal(format!("pid {pid} not in 0..{nprocs}")));
        }
        let rv = {
            let mut reg = registry().lock().unwrap();
            reg.entry(master.to_string())
                .or_insert_with(|| {
                    Arc::new(Rendezvous {
                        nprocs,
                        platform: platform.clone(),
                        state: Mutex::new(RendezvousState::default()),
                        cv: Condvar::new(),
                    })
                })
                .clone()
        };
        if rv.nprocs != nprocs {
            return Err(LpfError::Illegal(format!(
                "master {master}: peer expects {} processes, this one {nprocs}",
                rv.nprocs
            )));
        }
        if rv.platform != platform {
            // Report the actual disagreement: without this check the
            // first arrival's platform silently won, and a same-nprocs
            // rendezvous over a different platform either "succeeded" on
            // the wrong fabric or failed later with an unrelated error.
            return Err(LpfError::Illegal(format!(
                "master {master}: peer initialised platform {:?}, this process requests {:?}",
                rv.platform, platform
            )));
        }
        // Wait until all peers registered (the TCP accept loop analogue).
        let deadline = Instant::now() + timeout;
        let mut st = rv.state.lock().unwrap();
        st.arrived += 1;
        st.registered += 1;
        rv.cv.notify_all();
        while st.arrived < nprocs {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                let missing = nprocs - st.arrived;
                st.arrived -= 1;
                st.registered -= 1;
                drop(st);
                // Last one out retires the master address (same contract as
                // finalize), so a later retry may rendezvous with different
                // parameters instead of hitting a phantom peer forever.
                // Locks in registry→state order, matching do_finalize.
                let mut reg = registry().lock().unwrap();
                let st = rv.state.lock().unwrap();
                if st.registered == 0 && st.arrived == 0 {
                    reg.retain(|_, v| !Arc::ptr_eq(v, &rv));
                }
                return Err(LpfError::Fatal(format!(
                    "initialize_over_tcp timed out waiting for {missing} of {nprocs} peers"
                )));
            }
            let (g, _) = rv.cv.wait_timeout(st, left.min(Duration::from_millis(20))).unwrap();
            st = g;
        }
        rv.cv.notify_all();
        drop(st);
        Ok(Init {
            rendezvous: rv,
            pid,
            nprocs,
            epoch: AtomicU32::new(0),
            finalized: false,
        })
    }

    /// This process's id within the hooked context.
    pub fn pid(&self) -> Pid {
        self.pid
    }

    /// Number of processes the context will have.
    pub fn nprocs(&self) -> Pid {
        self.nprocs
    }

    /// Install (or clear) a deterministic fault-injection plan for the
    /// hook epochs over this rendezvous (see [`crate::netsim::faults`]).
    /// Takes effect from the next epoch's team hand-out; like the pool's
    /// [`crate::pool::Pool::set_fault_plan`], the plan object persists
    /// across epochs, so one-shot faults stay exhausted after firing and
    /// the next hook runs clean on a rebuilt team.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        self.rendezvous.state.lock().unwrap().fault_plan = plan;
    }

    /// `lpf_mpi_finalize`: release the init. The registry entry is removed
    /// when the last peer finalises, so the master address can be reused.
    pub fn finalize(mut self) {
        self.do_finalize();
    }

    fn do_finalize(&mut self) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        let mut reg = registry().lock().unwrap();
        let mut st = self.rendezvous.state.lock().unwrap();
        st.registered -= 1;
        if st.registered == 0 {
            // last one out: retire the master address
            reg.retain(|_, v| !Arc::ptr_eq(v, &self.rendezvous));
        }
    }
}

impl Drop for Init {
    fn drop(&mut self) {
        self.do_finalize();
    }
}

/// `lpf_hook`: enter an SPMD context from existing processes. May be called
/// any number of times while the `Init` is valid (paper §2.3); each call is
/// collective over all `nprocs` peers and presents a pristine context.
///
/// Hooks over one master ride a **warm team**: the first epoch builds the
/// context group (fabric, tuned barrier, arenas); every later epoch reuses
/// it through the same job-boundary reset the [`crate::pool::Pool`]
/// performs, so a host framework issuing many small LPF jobs (the paper's
/// §4.3 Spark integration) pays context construction once. An epoch whose
/// team aborted is not reused — the next hook builds a fresh group.
pub fn hook<O, F>(init: &Init, spmd: F, args: Args) -> Result<O>
where
    F: Fn(&mut Context, Args) -> O,
{
    if init.finalized {
        return Err(LpfError::Illegal("hook on finalized init".into()));
    }
    let epoch = init.epoch.fetch_add(1, Ordering::SeqCst) as u64;
    let rv = &init.rendezvous;
    // First arrival of this epoch takes the warm team (or builds one); all
    // peers wait for it. Epochs are serialised: epoch e may only start once
    // every peer left epoch e−1, which each peer's own program order
    // already implies for itself — the wait below extends it to the team.
    let group = {
        let mut guard = rv.state.lock().unwrap();
        while guard.completed < epoch {
            guard = rv.cv.wait(guard).unwrap();
        }
        let st = &mut *guard;
        let entry = st.groups.entry(epoch).or_insert_with(|| {
            let g = match st.warm.take() {
                Some(w) if w.healthy() => w, // already reset when stashed
                _ => ContextGroup::new(rv.platform.clone(), rv.nprocs),
            };
            // the hook-epoch path consults the same fault plan a pool
            // would: installed on fresh and warm teams alike
            g.fabric().set_fault_plan(st.fault_plan.clone());
            (g, 0)
        });
        entry.1 += 1;
        let g = entry.0.clone();
        if entry.1 == rv.nprocs {
            st.groups.remove(&epoch); // everyone has a handle
        }
        rv.cv.notify_all();
        g
    };
    let out = run_spmd(group.clone(), init.pid, &spmd, args);
    // Last peer out closes the epoch and stashes the team for the next one.
    {
        let mut st = rv.state.lock().unwrap();
        let n = st.finishers.entry(epoch).or_insert(0);
        *n += 1;
        if *n == rv.nprocs {
            st.finishers.remove(&epoch);
            st.completed = epoch + 1;
            if group.healthy() {
                group.reset_for_job();
                st.warm = Some(group);
            }
            rv.cv.notify_all();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{MSG_DEFAULT, SYNC_DEFAULT};

    /// Simulate a host framework: n worker threads, each creating its own
    /// Init over the same master and hooking an LPF context — the paper's
    /// Algorithm 3 shape.
    #[test]
    fn hook_joins_foreign_threads() {
        let n: Pid = 4;
        let outs: Vec<u32> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|pid| {
                    s.spawn(move || {
                        let init = Init::over_master(
                            "master-a:9001",
                            pid,
                            n,
                            Duration::from_secs(120),
                            Platform::shared().checked(true),
                        )
                        .unwrap();
                        let out = hook(
                            &init,
                            |ctx, _| {
                                // allgather of pids via puts (distinct
                                // source and destination slots, as in the
                                // paper's Algorithm 2)
                                ctx.resize_memory_register(2).unwrap();
                                ctx.resize_message_queue(ctx.p() as usize).unwrap();
                                ctx.sync(SYNC_DEFAULT).unwrap();
                                let mine = ctx.register_global(4).unwrap();
                                let all = ctx.register_global(4 * ctx.p() as usize).unwrap();
                                ctx.write_typed(mine, 0, &[ctx.pid()]).unwrap();
                                for k in 0..ctx.p() {
                                    ctx.put(
                                        mine,
                                        0,
                                        k,
                                        all,
                                        ctx.pid() as usize * 4,
                                        4,
                                        MSG_DEFAULT,
                                    )
                                    .unwrap();
                                }
                                ctx.sync(SYNC_DEFAULT).unwrap();
                                let mut pids = vec![0u32; ctx.p() as usize];
                                ctx.read_typed(all, 0, &mut pids).unwrap();
                                pids.iter().sum::<u32>()
                            },
                            Args::none(),
                        )
                        .unwrap();
                        init.finalize();
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(outs.iter().all(|&x| x == 0 + 1 + 2 + 3));
    }

    #[test]
    fn hook_multiple_times_same_init() {
        let n: Pid = 2;
        std::thread::scope(|s| {
            for pid in 0..n {
                s.spawn(move || {
                    let init = Init::over_master(
                        "master-b:9002",
                        pid,
                        n,
                        Duration::from_secs(120),
                        Platform::shared(),
                    )
                    .unwrap();
                    for round in 0..3u32 {
                        let out =
                            hook(&init, |ctx, _| ctx.pid() + 100, Args::none()).unwrap();
                        assert_eq!(out, pid + 100, "round {round}");
                    }
                });
            }
        });
    }

    #[test]
    fn hook_epochs_reuse_a_warm_team_with_fresh_state() {
        let n: Pid = 2;
        std::thread::scope(|s| {
            for pid in 0..n {
                s.spawn(move || {
                    let init = Init::over_master(
                        "master-warm:9005",
                        pid,
                        n,
                        Duration::from_secs(120),
                        Platform::shared().checked(true),
                    )
                    .unwrap();
                    // epoch 0: dirty the context — raise capacities,
                    // register a slot, never deregister it
                    let leaked = hook(
                        &init,
                        |ctx, _| {
                            ctx.resize_memory_register(4).unwrap();
                            ctx.resize_message_queue(8).unwrap();
                            ctx.sync(SYNC_DEFAULT).unwrap();
                            ctx.register_global(16).unwrap()
                        },
                        Args::none(),
                    )
                    .unwrap();
                    // epoch 1: warm team, pristine state
                    hook(
                        &init,
                        move |ctx, _| {
                            // capacities are back at their defaults
                            assert!(ctx.register_global(1).is_err());
                            // the leaked handle is from an earlier epoch
                            let mut buf = [0u8; 1];
                            let err = ctx.read_slot(leaked, 0, &mut buf).unwrap_err();
                            assert!(matches!(err, LpfError::Illegal(_)), "{err:?}");
                            // and stats restarted from zero
                            assert_eq!(ctx.stats().syncs, 0);
                        },
                        Args::none(),
                    )
                    .unwrap();
                    init.finalize();
                });
            }
        });
    }

    #[test]
    fn hook_epoch_consults_fault_plan_and_next_epoch_recovers() {
        use crate::netsim::faults::{FaultPlan, FaultSpec};
        let n: Pid = 2;
        let plan = FaultPlan::one(FaultSpec::AbortAtSuperstep { pid: 1, step: 0 });
        std::thread::scope(|s| {
            for pid in 0..n {
                let plan = plan.clone();
                s.spawn(move || {
                    let init = Init::over_master(
                        "master-fault:9008",
                        pid,
                        n,
                        Duration::from_secs(120),
                        Platform::shared().checked(true),
                    )
                    .unwrap();
                    init.set_fault_plan(Some(plan.clone()));
                    // epoch 0: the injected abort surfaces as a clean
                    // error on every peer — never a hang
                    let res = hook(
                        &init,
                        |ctx, _| {
                            ctx.resize_message_queue(1).unwrap();
                            ctx.sync(SYNC_DEFAULT).unwrap();
                        },
                        Args::none(),
                    );
                    assert!(res.is_err(), "pid {pid}: fault must surface");
                    // epoch 1: the aborted team is not reused; the fresh
                    // one shares the exhausted plan → clean run
                    let out = hook(&init, |ctx, _| ctx.pid(), Args::none()).unwrap();
                    assert_eq!(out, pid);
                    init.finalize();
                });
            }
        });
        assert_eq!(plan.injections(), 1, "the abort fired exactly once");
    }

    #[test]
    fn init_reports_platform_mismatch_explicitly() {
        const MASTER: &str = "master-plat:9006";
        // Peer A registers the master with the shared platform and waits.
        let a = std::thread::spawn(|| {
            Init::over_master(MASTER, 0, 2, Duration::from_secs(60), Platform::shared())
        });
        // Deterministic ordering: wait until A's registration is visible.
        while !registry().lock().unwrap().contains_key(MASTER) {
            std::thread::yield_now();
        }
        // A same-nprocs arrival on a different platform is rejected with an
        // explicit platform report, not a timeout or a silently wrong fabric.
        let b = Init::over_master(MASTER, 1, 2, Duration::from_millis(30), Platform::rdma());
        let err = match b {
            Err(e) => format!("{e:?}"),
            Ok(_) => panic!("platform mismatch must be rejected"),
        };
        assert!(err.contains("platform"), "explicit platform report: {err}");
        // A matching arrival completes the rendezvous normally.
        let peer =
            Init::over_master(MASTER, 1, 2, Duration::from_secs(60), Platform::shared()).unwrap();
        let a = a.join().unwrap().unwrap();
        a.finalize();
        peer.finalize();
    }

    #[test]
    fn timed_out_master_address_is_reusable() {
        // Every arrival timing out retires the address: a retry with
        // different parameters must start fresh instead of hitting a
        // phantom peer.
        let lonely = Init::over_master(
            "master-retry:9007",
            0,
            2,
            Duration::from_millis(20),
            Platform::shared(),
        );
        assert!(matches!(&lonely, Err(LpfError::Fatal(_))), "expected a timeout");
        assert!(!registry().lock().unwrap().contains_key("master-retry:9007"));
        // retry with a different platform AND nprocs succeeds
        let solo = Init::over_master(
            "master-retry:9007",
            0,
            1,
            Duration::from_millis(200),
            Platform::rdma(),
        )
        .unwrap();
        assert_eq!(solo.nprocs(), 1);
        solo.finalize();
    }

    #[test]
    fn init_timeout_when_peers_missing() {
        let res = Init::over_master(
            "master-lonely:9003",
            0,
            2,
            Duration::from_millis(50),
            Platform::shared(),
        );
        assert!(matches!(res, Err(LpfError::Fatal(_))));
    }

    #[test]
    fn init_rejects_bad_pid() {
        let res = Init::over_master(
            "master-bad:9004",
            5,
            2,
            Duration::from_millis(10),
            Platform::shared(),
        );
        assert!(matches!(res, Err(LpfError::Illegal(_))));
    }
}
