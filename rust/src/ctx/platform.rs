//! Platform selection: which of the paper's four LPF implementations a
//! context runs on (§3), plus their simulation parameters.

use std::sync::Arc;

use crate::core::Pid;
use crate::fabric::shared::SharedFabric;
use crate::fabric::Fabric;
use crate::netsim::Personality;

/// Which fabric `exec`/`hook` build a context on.
#[derive(Debug, Clone, PartialEq)]
pub enum Platform {
    /// Cache-coherent shared memory (the paper's Pthreads implementation).
    /// Real threads, real memcpy — wall-clock measurements are genuine.
    Shared { checked: bool },
    /// Distributed memory over two-sided message passing (the paper's MPI
    /// implementation), on the simulated NIC with the given personality.
    Msg { personality: Personality, checked: bool },
    /// Distributed memory over one-sided RDMA (the paper's ibverbs
    /// implementation), on the simulated NIC.
    Rdma { personality: Personality, checked: bool },
    /// Clusters of multicores: intra-node shared + inter-node distributed
    /// (the paper's hybrid implementation). `q` = processes per node.
    Hybrid { q: Pid, personality: Personality, checked: bool },
}

impl Platform {
    /// Shared-memory platform, unchecked (release defaults).
    pub fn shared() -> Self {
        Platform::Shared { checked: cfg!(debug_assertions) }
    }

    /// Message-passing platform with the default (compliant) personality.
    pub fn msg() -> Self {
        Platform::Msg { personality: Personality::ibverbs(), checked: false }
    }

    /// RDMA platform with the ibverbs personality.
    pub fn rdma() -> Self {
        Platform::Rdma { personality: Personality::ibverbs(), checked: false }
    }

    /// Hybrid platform with `q` processes per simulated node.
    pub fn hybrid(q: Pid) -> Self {
        Platform::Hybrid { q, personality: Personality::ibverbs(), checked: false }
    }

    /// Toggle per-superstep legality checking.
    pub fn checked(mut self, on: bool) -> Self {
        match &mut self {
            Platform::Shared { checked }
            | Platform::Msg { checked, .. }
            | Platform::Rdma { checked, .. }
            | Platform::Hybrid { checked, .. } => *checked = on,
        }
        self
    }

    /// Override the NIC personality (no-op for `Shared`).
    pub fn with_personality(mut self, p: Personality) -> Self {
        match &mut self {
            Platform::Shared { .. } => {}
            Platform::Msg { personality, .. }
            | Platform::Rdma { personality, .. }
            | Platform::Hybrid { personality, .. } => *personality = p,
        }
        self
    }

    /// Instantiate the fabric for `p` processes.
    pub(crate) fn make_fabric(&self, p: Pid) -> Arc<dyn Fabric> {
        match self {
            Platform::Shared { checked } => SharedFabric::new(p, *checked),
            Platform::Msg { personality, checked } => {
                crate::fabric::msg::MsgFabric::new(p, personality.clone(), *checked)
            }
            Platform::Rdma { personality, checked } => {
                crate::fabric::rdma::RdmaFabric::new(p, personality.clone(), *checked)
            }
            Platform::Hybrid { q, personality, checked } => {
                crate::fabric::hybrid::HybridFabric::new(p, *q, personality.clone(), *checked)
            }
        }
    }
}

impl Default for Platform {
    fn default() -> Self {
        Platform::shared()
    }
}
