//! Platform selection: which of the paper's four LPF implementations a
//! context runs on (§3), plus their simulation parameters.

use std::sync::Arc;

use crate::core::Pid;
use crate::fabric::net::DEFAULT_BRUCK_SEED;
use crate::fabric::shared::SharedFabric;
use crate::fabric::Fabric;
use crate::netsim::Personality;

/// Which fabric `exec`/`hook` build a context on.
///
/// The distributed variants carry a `seed`: the base of the randomised
/// Bruck meta-exchange schedule. A fabric derives its per-job schedule
/// from `(seed, job epoch)` — reproducible, but never replaying one
/// hard-coded schedule across fabrics and warm jobs.
#[derive(Debug, Clone, PartialEq)]
pub enum Platform {
    /// Cache-coherent shared memory (the paper's Pthreads implementation).
    /// Real threads, real memcpy — wall-clock measurements are genuine.
    Shared { checked: bool },
    /// Distributed memory over two-sided message passing (the paper's MPI
    /// implementation), on the simulated NIC with the given personality.
    Msg { personality: Personality, checked: bool, seed: u64 },
    /// Distributed memory over one-sided RDMA (the paper's ibverbs
    /// implementation), on the simulated NIC.
    Rdma { personality: Personality, checked: bool, seed: u64 },
    /// Clusters of multicores: intra-node shared + inter-node distributed
    /// (the paper's hybrid implementation). `q` = processes per node.
    Hybrid { q: Pid, personality: Personality, checked: bool, seed: u64 },
}

impl Platform {
    /// Shared-memory platform, unchecked (release defaults).
    pub fn shared() -> Self {
        Platform::Shared { checked: cfg!(debug_assertions) }
    }

    /// Message-passing platform with the default (compliant) personality.
    pub fn msg() -> Self {
        Platform::Msg {
            personality: Personality::ibverbs(),
            checked: false,
            seed: DEFAULT_BRUCK_SEED,
        }
    }

    /// RDMA platform with the ibverbs personality.
    pub fn rdma() -> Self {
        Platform::Rdma {
            personality: Personality::ibverbs(),
            checked: false,
            seed: DEFAULT_BRUCK_SEED,
        }
    }

    /// Hybrid platform with `q` processes per simulated node.
    pub fn hybrid(q: Pid) -> Self {
        Platform::Hybrid {
            q,
            personality: Personality::ibverbs(),
            checked: false,
            seed: DEFAULT_BRUCK_SEED,
        }
    }

    /// Toggle per-superstep legality checking.
    pub fn checked(mut self, on: bool) -> Self {
        match &mut self {
            Platform::Shared { checked }
            | Platform::Msg { checked, .. }
            | Platform::Rdma { checked, .. }
            | Platform::Hybrid { checked, .. } => *checked = on,
        }
        self
    }

    /// Override the NIC personality (no-op for `Shared`).
    pub fn with_personality(mut self, p: Personality) -> Self {
        match &mut self {
            Platform::Shared { .. } => {}
            Platform::Msg { personality, .. }
            | Platform::Rdma { personality, .. }
            | Platform::Hybrid { personality, .. } => *personality = p,
        }
        self
    }

    /// Override the meta-exchange base seed (no-op for `Shared`, which
    /// has no randomised router).
    pub fn with_seed(mut self, s: u64) -> Self {
        match &mut self {
            Platform::Shared { .. } => {}
            Platform::Msg { seed, .. }
            | Platform::Rdma { seed, .. }
            | Platform::Hybrid { seed, .. } => *seed = s,
        }
        self
    }

    /// The meta-exchange base seed (`None` for `Shared`).
    pub fn seed(&self) -> Option<u64> {
        match self {
            Platform::Shared { .. } => None,
            Platform::Msg { seed, .. }
            | Platform::Rdma { seed, .. }
            | Platform::Hybrid { seed, .. } => Some(*seed),
        }
    }

    /// Instantiate the fabric for `p` processes.
    pub(crate) fn make_fabric(&self, p: Pid) -> Arc<dyn Fabric> {
        match self {
            Platform::Shared { checked } => SharedFabric::new(p, *checked),
            Platform::Msg { personality, checked, seed } => {
                crate::fabric::msg::MsgFabric::with_seed(p, personality.clone(), *checked, *seed)
            }
            // the RDMA platform routes meta directly (no randomised
            // schedule); its seed only matters for the Bruck ablation
            // variant, which is constructed explicitly in benches
            Platform::Rdma { personality, checked, .. } => {
                crate::fabric::rdma::RdmaFabric::new(p, personality.clone(), *checked)
            }
            Platform::Hybrid { q, personality, checked, seed } => {
                crate::fabric::hybrid::HybridFabric::with_seed(
                    p,
                    *q,
                    personality.clone(),
                    *checked,
                    *seed,
                )
            }
        }
    }
}

impl Default for Platform {
    fn default() -> Self {
        Platform::shared()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_seed_defaults_and_overrides() {
        assert_eq!(Platform::shared().seed(), None);
        assert_eq!(Platform::msg().seed(), Some(DEFAULT_BRUCK_SEED));
        assert_eq!(Platform::hybrid(2).with_seed(42).seed(), Some(42));
        // the seed participates in platform identity (Init rendezvous
        // mismatch reporting)
        assert_ne!(Platform::msg(), Platform::msg().with_seed(7));
        // Shared has no randomised router: with_seed is a no-op
        assert_eq!(Platform::shared().with_seed(9), Platform::shared());
    }

    #[test]
    fn platform_seed_reaches_the_fabric_schedule() {
        let fab = Platform::hybrid(2).with_seed(0xABCD).make_fabric(4);
        // downcast-free check: the hybrid fabric reports its job-0 meta
        // seed through the netsim-backed constructor
        let net = crate::fabric::hybrid::HybridFabric::with_seed(
            4,
            2,
            Personality::ibverbs(),
            false,
            0xABCD,
        );
        assert_eq!(net.meta_seed(), Some(0xABCD));
        assert_eq!(fab.name(), "hybrid");
    }
}
