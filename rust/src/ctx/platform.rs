//! Platform selection: which of the paper's four LPF implementations a
//! context runs on (§3), plus their simulation parameters.

use std::sync::Arc;

use crate::core::{LpfError, Pid, Result};
use crate::fabric::net::{Topology, DEFAULT_BRUCK_SEED};
use crate::fabric::shared::SharedFabric;
use crate::fabric::Fabric;
use crate::netsim::Personality;

/// Which inter-node wiring a hybrid platform's nodes hang off.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HybridShape {
    /// All nodes on one crossbar: any inter-node route is one wire hop
    /// (the [`Topology::numa_pair`] shape).
    NumaPair,
    /// Node pairs under leaf switches under a root: one or two wire
    /// hops depending on the leaf (the [`Topology::fat_tree`] shape).
    FatTree,
}

/// Which fabric `exec`/`hook` build a context on.
///
/// The distributed variants carry a `seed`: the base of the randomised
/// Bruck meta-exchange schedule. A fabric derives its per-job schedule
/// from `(seed, job epoch)` — reproducible, but never replaying one
/// hard-coded schedule across fabrics and warm jobs.
#[derive(Debug, Clone, PartialEq)]
pub enum Platform {
    /// Cache-coherent shared memory (the paper's Pthreads implementation).
    /// Real threads, real memcpy — wall-clock measurements are genuine.
    Shared { checked: bool },
    /// Distributed memory over two-sided message passing (the paper's MPI
    /// implementation), on the simulated NIC with the given personality.
    Msg { personality: Personality, checked: bool, seed: u64 },
    /// Distributed memory over one-sided RDMA (the paper's ibverbs
    /// implementation), on the simulated NIC.
    Rdma { personality: Personality, checked: bool, seed: u64 },
    /// Clusters of multicores: intra-node shared + inter-node distributed
    /// (the paper's hybrid implementation). The explicit shape is
    /// `{nodes, procs_per_node}`: `nodes == 0` means "infer from p", and
    /// a job whose `p` doesn't factor into the shape fails with a clean
    /// `Illegal` (see [`Platform::validate`]) rather than silently
    /// leaving a partial node.
    Hybrid {
        /// Number of simulated nodes; 0 = infer as `p / procs_per_node`.
        nodes: Pid,
        /// Processes per simulated node (the paper's `q`).
        procs_per_node: Pid,
        /// Inter-node wiring the shape routes onto.
        shape: HybridShape,
        personality: Personality,
        checked: bool,
        seed: u64,
    },
}

impl Platform {
    /// Shared-memory platform, unchecked (release defaults).
    pub fn shared() -> Self {
        Platform::Shared { checked: cfg!(debug_assertions) }
    }

    /// Message-passing platform with the default (compliant) personality.
    pub fn msg() -> Self {
        Platform::Msg {
            personality: Personality::ibverbs(),
            checked: false,
            seed: DEFAULT_BRUCK_SEED,
        }
    }

    /// RDMA platform with the ibverbs personality.
    pub fn rdma() -> Self {
        Platform::Rdma {
            personality: Personality::ibverbs(),
            checked: false,
            seed: DEFAULT_BRUCK_SEED,
        }
    }

    /// Hybrid platform with `q` processes per simulated node on the
    /// NumaPair (crossbar) wiring; the node count is inferred from `p`.
    pub fn hybrid(q: Pid) -> Self {
        Platform::Hybrid {
            nodes: 0,
            procs_per_node: q,
            shape: HybridShape::NumaPair,
            personality: Personality::ibverbs(),
            checked: false,
            seed: DEFAULT_BRUCK_SEED,
        }
    }

    /// Hybrid platform with an explicit `{nodes, procs_per_node}` shape:
    /// jobs must launch exactly `nodes · procs_per_node` processes.
    pub fn hybrid_shaped(nodes: Pid, procs_per_node: Pid) -> Self {
        match Self::hybrid(procs_per_node) {
            Platform::Hybrid { procs_per_node, shape, personality, checked, seed, .. } => {
                Platform::Hybrid { nodes, procs_per_node, shape, personality, checked, seed }
            }
            _ => unreachable!(),
        }
    }

    /// Hybrid platform on the two-level FatTree wiring (`q` processes
    /// per node, node pairs under leaf switches under a root).
    pub fn hybrid_fat_tree(q: Pid) -> Self {
        match Self::hybrid(q) {
            Platform::Hybrid { nodes, procs_per_node, personality, checked, seed, .. } => {
                Platform::Hybrid {
                    nodes,
                    procs_per_node,
                    shape: HybridShape::FatTree,
                    personality,
                    checked,
                    seed,
                }
            }
            _ => unreachable!(),
        }
    }

    /// Check that a job of `p` processes fits this platform's shape.
    /// Only an **explicit** `Hybrid` shape constrains `p`: the inferred
    /// form (`nodes == 0`, the [`Platform::hybrid`] builder) tolerates a
    /// ragged last node — the topology layer places `p.div_ceil(q)`
    /// nodes and simply under-fills the last one — but a declared node
    /// count must factor `p` exactly, and `procs_per_node` must be ≥ 1
    /// either way.
    pub fn validate(&self, p: Pid) -> Result<()> {
        if let Platform::Hybrid { nodes, procs_per_node, .. } = self {
            let q = *procs_per_node;
            if q == 0 {
                return Err(LpfError::Illegal(
                    "hybrid shape: procs_per_node must be >= 1".into(),
                ));
            }
            if *nodes != 0 {
                if p % q != 0 {
                    return Err(LpfError::Illegal(format!(
                        "hybrid shape: p = {p} is not divisible by procs_per_node = {q}"
                    )));
                }
                if *nodes * q != p {
                    return Err(LpfError::Illegal(format!(
                        "hybrid shape: {nodes} nodes x {q} procs_per_node != p = {p}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Toggle per-superstep legality checking.
    pub fn checked(mut self, on: bool) -> Self {
        match &mut self {
            Platform::Shared { checked }
            | Platform::Msg { checked, .. }
            | Platform::Rdma { checked, .. }
            | Platform::Hybrid { checked, .. } => *checked = on,
        }
        self
    }

    /// Override the NIC personality (no-op for `Shared`).
    pub fn with_personality(mut self, p: Personality) -> Self {
        match &mut self {
            Platform::Shared { .. } => {}
            Platform::Msg { personality, .. }
            | Platform::Rdma { personality, .. }
            | Platform::Hybrid { personality, .. } => *personality = p,
        }
        self
    }

    /// Override the meta-exchange base seed (no-op for `Shared`, which
    /// has no randomised router).
    pub fn with_seed(mut self, s: u64) -> Self {
        match &mut self {
            Platform::Shared { .. } => {}
            Platform::Msg { seed, .. }
            | Platform::Rdma { seed, .. }
            | Platform::Hybrid { seed, .. } => *seed = s,
        }
        self
    }

    /// The meta-exchange base seed (`None` for `Shared`).
    pub fn seed(&self) -> Option<u64> {
        match self {
            Platform::Shared { .. } => None,
            Platform::Msg { seed, .. }
            | Platform::Rdma { seed, .. }
            | Platform::Hybrid { seed, .. } => Some(*seed),
        }
    }

    /// Instantiate the fabric for `p` processes.
    pub(crate) fn make_fabric(&self, p: Pid) -> Arc<dyn Fabric> {
        match self {
            Platform::Shared { checked } => SharedFabric::new(p, *checked),
            Platform::Msg { personality, checked, seed } => {
                crate::fabric::msg::MsgFabric::with_seed(p, personality.clone(), *checked, *seed)
            }
            // the RDMA platform routes meta directly (no randomised
            // schedule); its seed only matters for the Bruck ablation
            // variant, which is constructed explicitly in benches
            Platform::Rdma { personality, checked, .. } => {
                crate::fabric::rdma::RdmaFabric::new(p, personality.clone(), *checked)
            }
            Platform::Hybrid { procs_per_node, shape, personality, checked, seed, .. } => {
                let q = *procs_per_node;
                let topo = match shape {
                    // q ≤ 1 degenerates to Flat either way
                    HybridShape::NumaPair => Topology::clustered(q),
                    HybridShape::FatTree if q > 1 => Topology::fat_tree(q),
                    HybridShape::FatTree => Topology::flat(),
                };
                crate::fabric::hybrid::HybridFabric::with_topology(
                    p,
                    topo,
                    personality.clone(),
                    *checked,
                    *seed,
                )
            }
        }
    }
}

impl Default for Platform {
    fn default() -> Self {
        Platform::shared()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn platform_seed_defaults_and_overrides() {
        assert_eq!(Platform::shared().seed(), None);
        assert_eq!(Platform::msg().seed(), Some(DEFAULT_BRUCK_SEED));
        assert_eq!(Platform::hybrid(2).with_seed(42).seed(), Some(42));
        // the seed participates in platform identity (Init rendezvous
        // mismatch reporting)
        assert_ne!(Platform::msg(), Platform::msg().with_seed(7));
        // Shared has no randomised router: with_seed is a no-op
        assert_eq!(Platform::shared().with_seed(9), Platform::shared());
    }

    #[test]
    fn platform_seed_reaches_the_fabric_schedule() {
        let fab = Platform::hybrid(2).with_seed(0xABCD).make_fabric(4);
        // downcast-free check: the hybrid fabric reports its job-0 meta
        // seed through the netsim-backed constructor
        let net = crate::fabric::hybrid::HybridFabric::with_seed(
            4,
            2,
            Personality::ibverbs(),
            false,
            0xABCD,
        );
        assert_eq!(net.meta_seed(), Some(0xABCD));
        assert_eq!(fab.name(), "hybrid");
    }

    #[test]
    fn hybrid_shape_validation_is_clean_illegal() {
        assert!(Platform::hybrid(2).validate(4).is_ok());
        // the inferred shape tolerates a ragged last node (legacy q
        // semantics; the topology layer under-fills node p.div_ceil(q)−1)
        assert!(Platform::hybrid(2).validate(5).is_ok(), "inferred shape allows ragged p");
        assert!(Platform::hybrid(0).validate(4).is_err(), "zero procs_per_node");
        assert!(Platform::hybrid_shaped(2, 2).validate(4).is_ok());
        assert!(Platform::hybrid_shaped(3, 2).validate(4).is_err(), "wrong node count");
        assert!(Platform::shared().validate(7).is_ok(), "only hybrid constrains p");
        match Platform::hybrid_shaped(2, 2).validate(5) {
            Err(LpfError::Illegal(msg)) => assert!(msg.contains("divisible")),
            other => panic!("expected Illegal, got {other:?}"),
        }
    }

    #[test]
    fn hybrid_shapes_route_onto_their_topologies() {
        let numa = Platform::hybrid(2).make_fabric(4);
        assert_eq!(numa.topology().name, "numa_pair");
        let fat = Platform::hybrid_fat_tree(2).make_fabric(8);
        assert_eq!(fat.topology().name, "fat_tree");
        assert_eq!(fat.topology().levels, 2);
        assert_eq!(fat.topology().nodes, 4);
        assert_eq!(fat.topology().procs_per_node, 2);
        // q = 1 degenerates to flat regardless of the requested wiring
        assert_eq!(Platform::hybrid_fat_tree(1).make_fabric(4).topology().name, "flat");
    }
}
