//! Contexts and the twelve LPF primitives (paper §2, Fig. 1).
//!
//! The middle column is the raw, byte-addressed port of the C API; the
//! right column is its typed API-v2 equivalent (see [`crate::typed`]),
//! layered on the raw primitives without changing their semantics.
//!
//! | paper                        | raw (v1)                            | typed (v2)                       |
//! |------------------------------|-------------------------------------|----------------------------------|
//! | `lpf_exec`                   | [`exec`]                            | —                                |
//! | `lpf_hook`                   | [`hook`] + [`Init`]                 | —                                |
//! | `lpf_rehook`                 | [`Context::rehook`]                 | —                                |
//! | `lpf_register_local`         | [`Context::register_local`]         | [`Context::alloc_local`]         |
//! | `lpf_register_global`        | [`Context::register_global`]        | [`Context::alloc_global`]        |
//! | `lpf_deregister`             | [`Context::deregister`]             | [`Context::dealloc`]             |
//! | `lpf_put`                    | [`Context::put`]                    | [`Epoch::put_slice`]             |
//! | `lpf_get`                    | [`Context::get`]                    | [`Epoch::get_slice`]             |
//! | `lpf_sync`                   | [`Context::sync`]                   | [`Context::superstep`] (on exit) |
//! | `lpf_probe`                  | [`Context::probe`]                  | [`Epoch::probe`]                 |
//! | `lpf_resize_memory_register` | [`Context::resize_memory_register`] | [`Context::bootstrap`]           |
//! | `lpf_resize_message_queue`   | [`Context::resize_message_queue`]   | [`Context::bootstrap`]           |
//!
//! Slot access helpers: raw [`Context::read_slot`] / [`Context::write_slot`]
//! (bytes) correspond to typed [`Context::read`] / [`Context::write`] /
//! [`Context::read_vec`] (elements of any [`Pod`] type on a [`TypedSlot`]).
//!
//! SPMD functions are Rust closures `Fn(&mut Context, Args) -> O`; `exec`
//! spawns new processes (threads), `hook` enters a context from *existing*
//! processes (the interoperability mechanism of §2.3/§4.3), and `rehook`
//! temporarily replaces an active context with a pristine one (library
//! encapsulation).

mod init;
mod platform;

pub use init::{hook, Init};
pub use platform::Platform;

pub use crate::typed::{Epoch, TypedSlot};

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

use crate::core::{Args, LpfError, MachineParams, Memslot, MsgAttr, Pid, Result, SyncAttr};
use crate::fabric::Fabric;
use crate::memory::SlotStorage;
use crate::probe::ProbeTable;
use crate::queue::{GetReq, MsgQueue, PutReq};

/// The immutable team half of a context group: what a persistent worker
/// team ([`crate::pool::Pool`]) keeps alive across the SPMD jobs it serves.
/// Building this is the expensive part of context creation — the fabric
/// owns the barrier, the sync-plan arenas, and the slot registers.
pub(crate) struct TeamState {
    fabric: Arc<dyn Fabric>,
    platform: Platform,
    probe: Arc<ProbeTable>,
}

/// The per-job half: state that must not leak from one SPMD job into the
/// next. Reset by [`ContextGroup::reset_for_job`] instead of rebuilt.
pub(crate) struct JobState {
    /// Slot used by `rehook` to hand the pristine child group to peers.
    child: Mutex<Option<Arc<ContextGroup>>>,
}

/// State shared by the `p` processes of one context: an immutable
/// [`TeamState`] plus resettable [`JobState`].
pub(crate) struct ContextGroup {
    team: TeamState,
    job: JobState,
}

impl ContextGroup {
    pub(crate) fn new(platform: Platform, p: Pid) -> Arc<Self> {
        Arc::new(ContextGroup {
            team: TeamState {
                fabric: platform.make_fabric(p),
                platform,
                probe: ProbeTable::global(),
            },
            job: JobState { child: Mutex::new(None) },
        })
    }

    pub(crate) fn fabric(&self) -> &Arc<dyn Fabric> {
        &self.team.fabric
    }

    pub(crate) fn platform(&self) -> &Platform {
        &self.team.platform
    }

    /// Whether the team survived its last job: an aborted fabric has torn
    /// barrier episodes and cannot be reused warm.
    pub(crate) fn healthy(&self) -> bool {
        !self.team.fabric.aborted()
    }

    /// Job-boundary reset: clear every piece of per-job state so the next
    /// SPMD job observes a context bit-identical in behaviour to a freshly
    /// built one, while the team (threads, fabric, tuned barrier, arenas)
    /// stays warm. Caller guarantees no process is inside the fabric.
    pub(crate) fn reset_for_job(&self) {
        self.team.fabric.reset_for_job();
        *self.job.child.lock().expect("child slot poisoned") = None;
    }
}

/// The LPF run-time state handed to an SPMD function (`lpf_t`).
///
/// Not `Send`/`Sync`: a context belongs to exactly one process, and a
/// process is active in at most one context at a time (paper §2.1 —
/// contexts put on hold by `exec`/`rehook` are represented by `&mut`
/// reborrow exclusivity).
pub struct Context {
    pid: Pid,
    p: Pid,
    group: Arc<ContextGroup>,
    queue: MsgQueue,
    /// True between [`sync_begin`](Context::sync_begin) and
    /// [`sync_end`](Context::sync_end): the data exchange is in flight, so
    /// enqueueing puts/gets or syncing again is `Illegal` until the end
    /// half completes the fence.
    split_in_flight: bool,
    /// Set when the SPMD function completes normally; `Drop` otherwise
    /// marks the process aborted so peers fail fatally instead of hanging.
    clean: bool,
}

impl Context {
    pub(crate) fn new(group: Arc<ContextGroup>, pid: Pid) -> Self {
        let p = group.fabric().p();
        Context { pid, p, group, queue: MsgQueue::new(), split_in_flight: false, clean: false }
    }

    /// This process's id `s ∈ {0, …, p−1}`.
    pub fn pid(&self) -> Pid {
        self.pid
    }

    /// Number of processes `p` in this context.
    pub fn p(&self) -> Pid {
        self.p
    }

    // ---------------------------------------------------------- registration

    /// Fault-injection consult for the registration path (adversarial
    /// testing only; a no-op without an installed plan). Runs *before*
    /// any allocation or table mutation, so an injected failure honours
    /// the mitigable no-side-effects contract.
    fn registration_fault(&self) -> Result<()> {
        match self.group.fabric().fault_plan() {
            Some(plan) => plan.register_injection(self.pid),
            None => Ok(()),
        }
    }

    /// `lpf_register_local`: O(1) amortised; the slot is visible only to
    /// this process. Storage is owned by the register (zero-initialised).
    pub fn register_local(&mut self, len: usize) -> Result<Memslot> {
        self.registration_fault()?;
        self.group.fabric().register_of(self.pid).with_mut(|r| {
            // Reuse a parked same-sized block (re-zeroed) when one exists:
            // a warm job re-registering the windows of the previous job —
            // the serve layer's batched dispatch — allocates nothing.
            let storage = match r.take_recycled(len) {
                Some(s) => s,
                None => SlotStorage::new(len)?,
            };
            r.register_local(storage)
        })
    }

    /// `lpf_register_global`: collective; ids align across processes when
    /// every process performs the same sequence of global (de)registrations
    /// — the LPF contract. Takes effect for communication at the next
    /// `sync`, exactly as in the paper's Algorithm 2.
    pub fn register_global(&mut self, len: usize) -> Result<Memslot> {
        self.registration_fault()?;
        self.group.fabric().register_of(self.pid).with_mut(|r| {
            let storage = match r.take_recycled(len) {
                Some(s) => s,
                None => SlotStorage::new(len)?,
            };
            r.register_global(storage)
        })
    }

    /// `lpf_deregister`: O(1); frees the slot for reuse.
    pub fn deregister(&mut self, slot: Memslot) -> Result<()> {
        self.group.fabric().register_of(self.pid).with_mut(|r| r.deregister(slot))
    }

    /// `lpf_resize_memory_register`: O(N); active after the next `sync`.
    pub fn resize_memory_register(&mut self, max_slots: usize) -> Result<()> {
        self.group.fabric().register_of(self.pid).with_mut(|r| r.resize(max_slots))
    }

    /// `lpf_resize_message_queue`: O(N); active after the next `sync`.
    pub fn resize_message_queue(&mut self, max_msgs: usize) -> Result<()> {
        self.queue.resize(max_msgs)
    }

    // ---------------------------------------------------------- slot access

    /// Read bytes from one of this process's slots (outside communication).
    pub fn read_slot(&self, slot: Memslot, off: usize, out: &mut [u8]) -> Result<()> {
        let st = self.group.fabric().register_of(self.pid).resolve(slot)?;
        if off + out.len() > st.len() {
            return Err(LpfError::Illegal(format!(
                "read {off}+{} beyond slot of {}",
                out.len(),
                st.len()
            )));
        }
        // SAFETY: superstep discipline — no communication in flight.
        out.copy_from_slice(unsafe { &st.bytes()[off..off + out.len()] });
        Ok(())
    }

    /// Write bytes into one of this process's slots (outside communication).
    pub fn write_slot(&mut self, slot: Memslot, off: usize, data: &[u8]) -> Result<()> {
        let st = self.group.fabric().register_of(self.pid).resolve(slot)?;
        if off + data.len() > st.len() {
            return Err(LpfError::Illegal(format!(
                "write {off}+{} beyond slot of {}",
                data.len(),
                st.len()
            )));
        }
        // SAFETY: superstep discipline; this process owns the slot.
        unsafe { st.bytes_mut()[off..off + data.len()].copy_from_slice(data) };
        Ok(())
    }

    /// Closure access to a slot's bytes (owner, outside communication).
    pub fn with_slot_mut<T>(&mut self, slot: Memslot, f: impl FnOnce(&mut [u8]) -> T) -> Result<T> {
        let st = self.group.fabric().register_of(self.pid).resolve(slot)?;
        // SAFETY: superstep discipline; this process owns the slot.
        Ok(f(unsafe { st.bytes_mut() }))
    }

    /// Closure read access to a slot's bytes.
    pub fn with_slot<T>(&self, slot: Memslot, f: impl FnOnce(&[u8]) -> T) -> Result<T> {
        let st = self.group.fabric().register_of(self.pid).resolve(slot)?;
        // SAFETY: superstep discipline.
        Ok(f(unsafe { st.bytes() }))
    }

    /// Typed write helper: `data` as little-endian machine words.
    pub fn write_typed<T: Pod>(&mut self, slot: Memslot, elem_off: usize, data: &[T]) -> Result<()> {
        self.write_slot(slot, elem_off * size_of::<T>(), pod_bytes(data))
    }

    /// Typed read helper.
    pub fn read_typed<T: Pod>(&self, slot: Memslot, elem_off: usize, out: &mut [T]) -> Result<()> {
        let st = self.group.fabric().register_of(self.pid).resolve(slot)?;
        let off = elem_off * size_of::<T>();
        let len = size_of_val(out);
        if off + len > st.len() {
            return Err(LpfError::Illegal("typed read beyond slot".into()));
        }
        // SAFETY: superstep discipline + Pod invariant.
        unsafe {
            let src = &st.bytes()[off..off + len];
            std::ptr::copy_nonoverlapping(src.as_ptr(), out.as_mut_ptr() as *mut u8, len);
        }
        Ok(())
    }

    // ---------------------------------------------------------- communication

    /// Validate that `[off, off+len)` fits this process's `slot` — the O(1)
    /// enqueue-time check for the *local* side of a `put`/`get`. The remote
    /// side is validated by the destination during `sync` (remote global
    /// slots may have different lengths per process; only the registration
    /// order is required to align).
    fn check_local_range(&self, what: &str, slot: Memslot, off: usize, len: usize) -> Result<()> {
        let avail = self.group.fabric().register_of(self.pid).len_of(slot)?;
        match off.checked_add(len) {
            Some(end) if end <= avail => Ok(()),
            _ => Err(LpfError::Illegal(format!(
                "{what} range [{off}, {off}+{len}) exceeds local slot of {avail} B"
            ))),
        }
    }

    /// `lpf_put`: O(1), touches no payload; copy `len` bytes from local
    /// `(src_slot, src_off)` to `(dst_pid, dst_slot, dst_off)`. Completed
    /// only by the next `sync`. The local source range is validated here,
    /// at enqueue time — an out-of-bounds source fails fast with
    /// [`LpfError::Illegal`] and queues nothing, instead of surfacing as a
    /// confusing failure inside the next `sync`.
    pub fn put(
        &mut self,
        src_slot: Memslot,
        src_off: usize,
        dst_pid: Pid,
        dst_slot: Memslot,
        dst_off: usize,
        len: usize,
        attr: MsgAttr,
    ) -> Result<()> {
        self.check_quiescent("put")?;
        if dst_pid >= self.p {
            return Err(LpfError::Illegal(format!("dst pid {dst_pid} out of range {}", self.p)));
        }
        self.check_local_range("put source", src_slot, src_off, len)?;
        self.queue.push_put(PutReq { src_slot, src_off, dst_pid, dst_slot, dst_off, len, attr })
    }

    /// `lpf_get`: O(1), touches no payload; copy `len` bytes from
    /// `(src_pid, src_slot, src_off)` into local `(dst_slot, dst_off)`.
    /// The local destination range is validated here, at enqueue time (see
    /// [`put`](Context::put)).
    pub fn get(
        &mut self,
        src_pid: Pid,
        src_slot: Memslot,
        src_off: usize,
        dst_slot: Memslot,
        dst_off: usize,
        len: usize,
        attr: MsgAttr,
    ) -> Result<()> {
        self.check_quiescent("get")?;
        if src_pid >= self.p {
            return Err(LpfError::Illegal(format!("src pid {src_pid} out of range {}", self.p)));
        }
        self.check_local_range("get destination", dst_slot, dst_off, len)?;
        self.queue.push_get(GetReq { src_pid, src_slot, src_off, dst_slot, dst_off, len, attr })
    }

    /// Misuse guard: between `sync_begin` and `sync_end` the queue and the
    /// registered slots belong to the in-flight exchange — a clean, purely
    /// local `Illegal` (never a deadlock or corruption).
    fn check_quiescent(&self, what: &str) -> Result<()> {
        if self.split_in_flight {
            return Err(LpfError::Illegal(format!(
                "{what} while a split-phase superstep is in flight (sync_begin without sync_end)"
            )));
        }
        Ok(())
    }

    /// `lpf_sync`: execute the queued h-relation; `hg + ℓ` (paper §2.2).
    /// The only fence: all puts/gets issued before it are visible after it.
    pub fn sync(&mut self, attr: SyncAttr) -> Result<()> {
        self.check_quiescent("sync")?;
        let res = self.group.fabric().sync(self.pid, self.queue.requests(), attr);
        self.queue.clear();
        // Capacities become active "after a fence provided each call
        // completed successfully" (paper §2.2) — even a failed h-relation
        // leaves capacities consistent because activation is local.
        self.queue.activate_pending();
        self.group.fabric().register_of(self.pid).with_mut(|r| r.activate_pending());
        res
    }

    /// First half of a split-phase superstep: drains the queued h-relation,
    /// launches its data exchange, and returns control so local compute
    /// overlaps the in-flight transfer. Until [`sync_end`](Context::sync_end)
    /// completes the fence, `put`/`get`/`sync`/`sync_begin` return `Illegal`
    /// and registered slots must be left quiescent (the typed
    /// [`superstep_overlapped`](Context::superstep_overlapped) enforces the
    /// latter statically). Collective: every process must pair begin/end.
    pub fn sync_begin(&mut self, attr: SyncAttr) -> Result<()> {
        self.check_quiescent("sync_begin")?;
        let res = self.group.fabric().sync_begin(self.pid, self.queue.requests(), attr);
        self.queue.clear();
        if res.is_ok() {
            // Capacity activation waits for sync_end — the fence is not
            // complete while the exchange is in flight.
            self.split_in_flight = true;
        }
        res
    }

    /// Second half of a split-phase superstep: completes delivery and the
    /// fence begun by [`sync_begin`](Context::sync_begin); all puts/gets
    /// issued before the begin are visible after this returns. `Illegal`
    /// (purely local) if no split superstep is in flight.
    pub fn sync_end(&mut self) -> Result<()> {
        if !self.split_in_flight {
            return Err(LpfError::Illegal(
                "sync_end without a matching sync_begin".to_string(),
            ));
        }
        let res = self.group.fabric().sync_end(self.pid);
        self.split_in_flight = false;
        // The fence is complete (or the context fatally dead): capacities
        // activate exactly as at the end of a bulk sync.
        self.queue.activate_pending();
        self.group.fabric().register_of(self.pid).with_mut(|r| r.activate_pending());
        res
    }

    /// One split-phase superstep around a compute closure: `sync_begin`,
    /// run `compute` while the exchange is in flight, `sync_end`. The
    /// closure gets no context access, so it cannot enqueue or sync; it is
    /// the *caller's* contract that it leaves registered slots alone — use
    /// the typed [`superstep_overlapped`](Context::superstep_overlapped)
    /// for the statically checked form.
    pub fn sync_split<R>(&mut self, attr: SyncAttr, compute: impl FnOnce() -> R) -> Result<R> {
        self.sync_begin(attr)?;
        let out = compute();
        self.sync_end()?;
        Ok(out)
    }

    /// `lpf_probe`: Θ(1) lookup of the machine parameters underneath this
    /// context (offline-benchmarked table, falling back to conservative
    /// constants — paper §2.2 allows both).
    pub fn probe(&self) -> MachineParams {
        self.group.team.probe.lookup(self.group.fabric().name(), self.p)
    }

    /// `lpf_rehook`: temporarily replace this context with a pristine one
    /// running `spmd`; this context is on hold meanwhile (paper §2.1:
    /// "simplifies writing libraries").
    pub fn rehook<O, F>(&mut self, spmd: F, args: Args) -> Result<O>
    where
        F: Fn(&mut Context, Args) -> O,
    {
        let fabric = self.group.fabric();
        fabric.barrier(self.pid)?;
        if self.pid == 0 {
            let child = ContextGroup::new(self.group.platform().clone(), self.p);
            *self.group.job.child.lock().unwrap() = Some(child);
        }
        fabric.barrier(self.pid)?;
        let child = self
            .group
            .job
            .child
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| LpfError::Fatal("rehook: child group missing".into()))?;
        fabric.barrier(self.pid)?;
        if self.pid == 0 {
            *self.group.job.child.lock().unwrap() = None;
        }
        run_spmd(child, self.pid, &spmd, args)
    }

    /// Transport statistics (diagnostics; not part of the paper API).
    pub fn stats(&self) -> crate::fabric::SyncStats {
        self.group.fabric().stats(self.pid)
    }

    /// Simulated time for netsim-backed fabrics (None on real backends).
    pub fn sim_time_ns(&self) -> Option<f64> {
        self.group.fabric().sim_time_ns(self.pid)
    }

    /// Backend name ("shared", "msg", "rdma", "hybrid").
    pub fn backend(&self) -> &'static str {
        self.group.fabric().name()
    }

    /// The machine topology underneath this context: shape name, level
    /// count, and `{nodes, procs_per_node}`. Flat single-level on
    /// backends without a hierarchical topology. The collectives planner
    /// keys its two-level decomposition on `levels ≥ 2`.
    pub fn topology(&self) -> crate::fabric::TopologyView {
        self.group.fabric().topology()
    }
}

impl Drop for Context {
    fn drop(&mut self) {
        if !self.clean {
            // SPMD function unwound or returned early through `?`: mark the
            // context aborted so peers observe PeerAborted (paper §2.1's
            // natural error propagation without deadlocks).
            self.group.fabric().abort(self.pid);
        }
    }
}

/// Human-readable form of a panic payload (`&str` and `String` payloads —
/// what `panic!` produces — are quoted verbatim; anything else is labelled).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("<non-string panic payload>")
}

/// Run one process's SPMD body with abort-on-panic semantics.
pub(crate) fn run_spmd<O, F>(group: Arc<ContextGroup>, pid: Pid, spmd: &F, args: Args) -> Result<O>
where
    F: Fn(&mut Context, Args) -> O,
{
    let mut slab = MsgQueue::new();
    run_spmd_recycled(group, pid, spmd, args, &mut slab)
}

/// [`run_spmd`], recycling the caller's request-queue arena: the queue is
/// taken for the duration of the job and handed back (cleared, capacities
/// at defaults) afterwards. The pool's worker threads keep one slab per
/// process so a warm job dispatch performs no queue allocation.
pub(crate) fn run_spmd_recycled<O, F>(
    group: Arc<ContextGroup>,
    pid: Pid,
    spmd: &F,
    args: Args,
    slab: &mut MsgQueue,
) -> Result<O>
where
    F: Fn(&mut Context, Args) -> O,
{
    // Fabric constructors are infallible; a job whose p doesn't fit the
    // platform's declared shape (e.g. hybrid `{nodes, procs_per_node}`
    // with non-divisible p) fails here, before any process enters the
    // fabric — a clean, purely local `Illegal`, never a panic.
    group.platform().validate(group.fabric().p())?;
    slab.reset_for_job();
    let mut ctx = Context::new(group, pid);
    ctx.queue = std::mem::take(slab);
    let out = catch_unwind(AssertUnwindSafe(|| spmd(&mut ctx, args)));
    let res = match out {
        Ok(o) => {
            if ctx.split_in_flight {
                // sync_begin without sync_end at SPMD exit: leave `clean`
                // false so the drop below aborts the fabric — peers fail
                // with PeerAborted instead of hanging at sync_end's
                // barrier (the never-deadlock rule for split-phase misuse).
                Err(LpfError::Illegal(format!(
                    "SPMD function on pid {pid} returned with a split-phase \
                     superstep still in flight (sync_begin without sync_end)"
                )))
            } else {
                ctx.clean = true;
                Ok(o)
            }
        }
        Err(payload) => Err(LpfError::Fatal(format!(
            "SPMD function panicked on pid {pid}: {}",
            panic_message(payload.as_ref())
        ))),
    };
    *slab = std::mem::take(&mut ctx.queue);
    drop(ctx); // a non-clean drop marks the process aborted
    res
}

/// The sequential "root" context (`LPF_ROOT`): configuration from which
/// parallel contexts are launched.
#[derive(Debug, Clone)]
pub struct Root {
    platform: Platform,
    max_procs: Pid,
}

impl Root {
    /// Root over the given platform with a default process budget.
    pub fn new(platform: Platform) -> Self {
        let max = std::thread::available_parallelism().map(|n| n.get() as Pid).unwrap_or(1);
        // Oversubscription is meaningful for LPF (BSP processes are logical);
        // default budget mirrors the paper's testbeds scaled to this host.
        Root { platform, max_procs: max.max(8) }
    }

    /// Cap the number of processes `exec(MAX_P)` may create.
    pub fn with_max_procs(mut self, p: Pid) -> Self {
        self.max_procs = p.max(1);
        self
    }

    /// The platform this root launches onto.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }
}

impl Default for Root {
    /// `LPF_ROOT`: the shared-memory platform, checked in debug builds.
    fn default() -> Self {
        Root::new(Platform::default())
    }
}

/// `lpf_exec`: run `spmd` on `min(max_p, root budget)` new processes.
/// Returns every process's output (index = pid). Cost O(Ng + ℓ) with N the
/// argument size (one broadcast) plus process spawn.
///
/// Implemented as sugar over a transient single-job [`crate::pool::Pool`]:
/// one code path serves both the one-shot `exec` and the persistent
/// hot-team executor. Callers issuing *repeated* jobs should hold a shared
/// [`Pool`](crate::pool::Pool) instead — `Pool::exec` has the same
/// semantics but pays the spawn/teardown only once.
pub fn exec<O, F>(root: &Root, max_p: Pid, spmd: F, args: Args) -> Result<Vec<O>>
where
    F: Fn(&mut Context, Args) -> O + Sync,
    O: Send,
{
    let p = max_p.min(root.max_procs).max(1);
    // untuned: a single-job pool would discard the barrier calibration, so
    // one-shot exec keeps its pre-pool O(p) heuristic and first-call cost
    let pool = crate::pool::Pool::new_untuned(root.platform.clone(), p);
    pool.exec(spmd, args)
}

// ---------------------------------------------------------------- Pod bytes

/// Plain-old-data marker for typed slot access.
///
/// # Safety
/// Implementors must be valid for any bit pattern and contain no padding.
pub unsafe trait Pod: Copy + 'static {}
unsafe impl Pod for u8 {}
unsafe impl Pod for i8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for usize {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// Reinterpret a Pod slice as bytes.
pub fn pod_bytes<T: Pod>(data: &[T]) -> &[u8] {
    // SAFETY: Pod guarantees no padding and all bit patterns valid.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, size_of_val(data)) }
}

/// Reinterpret a Pod slice as mutable bytes (read targets need no
/// intermediate buffer: any bit pattern written is a valid `T`).
pub fn pod_bytes_mut<T: Pod>(data: &mut [T]) -> &mut [u8] {
    let len = size_of_val(data);
    // SAFETY: Pod guarantees no padding and all bit patterns valid.
    unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, len) }
}

use std::mem::{size_of, size_of_val};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{MSG_DEFAULT, SYNC_DEFAULT};

    fn root() -> Root {
        Root::new(Platform::shared().checked(true)).with_max_procs(8)
    }

    #[test]
    fn exec_spawns_requested_processes() {
        let outs = exec(&root(), 4, |ctx, _| (ctx.pid(), ctx.p()), Args::none()).unwrap();
        assert_eq!(outs, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn exec_caps_at_root_budget() {
        let outs = exec(&root(), crate::core::MAX_P, |ctx, _| ctx.p(), Args::none()).unwrap();
        assert_eq!(outs.len(), 8);
    }

    #[test]
    fn args_are_broadcast() {
        let outs = exec(
            &root(),
            3,
            |_, args| args.input.clone(),
            Args::input(vec![42u8, 7]),
        )
        .unwrap();
        assert!(outs.iter().all(|o| o == &vec![42, 7]));
    }

    /// The paper's Algorithm-2 pattern: resize, sync, register, get, sync.
    #[test]
    fn algorithm2_bootstrap_pattern() {
        let outs = exec(
            &root(),
            4,
            |ctx, args| {
                ctx.resize_memory_register(3).unwrap();
                ctx.resize_message_queue(2 * ctx.p() as usize).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                let mdim = ctx.register_global(8).unwrap();
                if ctx.pid() == 0 {
                    ctx.write_typed::<u32>(mdim, 0, &[u32::from_le_bytes(args.input[0..4].try_into().unwrap()), 77]).unwrap();
                }
                // everyone fetches the matrix size from root
                if ctx.pid() != 0 {
                    ctx.get(0, mdim, 0, mdim, 0, 8, MSG_DEFAULT).unwrap();
                }
                ctx.sync(SYNC_DEFAULT).unwrap();
                let mut dims = [0u32; 2];
                ctx.read_typed(mdim, 0, &mut dims).unwrap();
                ctx.deregister(mdim).unwrap();
                dims
            },
            Args::input(1000u32.to_le_bytes().to_vec()),
        )
        .unwrap();
        assert!(outs.iter().all(|&d| d == [1000, 77]));
    }

    #[test]
    fn crcw_error_broadcast_pattern() {
        // Algorithm 2's error broadcast: erroring pid puts its code to all.
        let outs = exec(
            &root(),
            4,
            |ctx, _| {
                ctx.resize_memory_register(2).unwrap();
                ctx.resize_message_queue(2 * ctx.p() as usize).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                let lerr = ctx.register_local(4).unwrap();
                let gerr = ctx.register_global(4).unwrap();
                let my_err: u32 = if ctx.pid() == 2 { 13 } else { 0 };
                ctx.write_typed(lerr, 0, &[my_err]).unwrap();
                if my_err != 0 {
                    for k in 0..ctx.p() {
                        ctx.put(lerr, 0, k, gerr, 0, 4, MSG_DEFAULT).unwrap();
                    }
                }
                ctx.sync(SYNC_DEFAULT).unwrap();
                let mut g = [0u32];
                ctx.read_typed(gerr, 0, &mut g).unwrap();
                g[0]
            },
            Args::none(),
        )
        .unwrap();
        assert_eq!(outs, vec![13, 13, 13, 13]);
    }

    #[test]
    fn queue_capacity_error_is_mitigable_mid_superstep() {
        exec(
            &root(),
            2,
            |ctx, _| {
                ctx.resize_memory_register(1).unwrap();
                ctx.resize_message_queue(1).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                // src range [0,4) and dst range [4,8) are disjoint: legal
                let s = ctx.register_global(8).unwrap();
                ctx.put(s, 0, (ctx.pid() + 1) % 2, s, 4, 4, MSG_DEFAULT).unwrap();
                let err = ctx.put(s, 0, 0, s, 4, 4, MSG_DEFAULT).unwrap_err();
                assert!(err.is_mitigable());
                // mitigate: raise the capacity, sync, retry
                ctx.resize_message_queue(8).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                ctx.put(s, 0, 0, s, 4, 4, MSG_DEFAULT).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
            },
            Args::none(),
        )
        .unwrap();
    }

    #[test]
    fn panic_in_one_process_is_fatal_for_all() {
        let res = exec(
            &root(),
            3,
            |ctx, _| {
                if ctx.pid() == 1 {
                    panic!("boom");
                }
                // peers block in a sync and must get PeerAborted, not hang
                ctx.resize_message_queue(1).unwrap();
                match ctx.sync(SYNC_DEFAULT) {
                    Err(LpfError::PeerAborted { .. }) => (),
                    other => panic!("expected PeerAborted, got {other:?}"),
                }
            },
            Args::none(),
        );
        assert!(res.is_err());
    }

    #[test]
    fn rehook_runs_pristine_nested_context() {
        let outs = exec(
            &root(),
            4,
            |ctx, _| {
                ctx.resize_memory_register(1).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                let outer_slot = ctx.register_global(4).unwrap();
                let inner = ctx
                    .rehook(
                        |inner_ctx, _| {
                            // pristine: fresh capacities (default zero)
                            assert!(inner_ctx.register_global(4).is_err());
                            inner_ctx.resize_memory_register(1).unwrap();
                            inner_ctx.sync(SYNC_DEFAULT).unwrap();
                            let s = inner_ctx.register_global(1).unwrap();
                            inner_ctx.deregister(s).unwrap();
                            inner_ctx.pid() * 10
                        },
                        Args::none(),
                    )
                    .unwrap();
                // outer context resumes intact
                ctx.deregister(outer_slot).unwrap();
                inner
            },
            Args::none(),
        )
        .unwrap();
        assert_eq!(outs, vec![0, 10, 20, 30]);
    }

    #[test]
    fn probe_returns_params_for_backend() {
        exec(
            &root(),
            2,
            |ctx, _| {
                let m = ctx.probe();
                assert_eq!(m.p, 2);
                assert!(!m.params.is_empty());
                assert!(m.h_relation_ns(100, 8) > 0.0);
            },
            Args::none(),
        )
        .unwrap();
    }

    #[test]
    fn nested_exec_spawns_fresh_processes() {
        let outs = exec(
            &root(),
            2,
            |ctx, _| {
                if ctx.pid() == 0 {
                    let inner_root = Root::new(Platform::shared()).with_max_procs(2);
                    let inner =
                        exec(&inner_root, 2, |c, _| c.p(), Args::none()).unwrap();
                    inner.len() as u32
                } else {
                    0
                }
            },
            Args::none(),
        )
        .unwrap();
        assert_eq!(outs[0], 2);
    }

    #[test]
    fn hybrid_shape_mismatch_is_a_clean_illegal_job_error() {
        let root = Root::new(Platform::hybrid_shaped(2, 2)).with_max_procs(8);
        // p = 5 doesn't fit the declared 2×2 shape: the job fails before
        // any process enters the fabric — no panic, no hang
        match exec(&root, 5, |ctx, _| ctx.pid(), Args::none()) {
            Err(LpfError::Illegal(msg)) => assert!(msg.contains("divisible"), "{msg}"),
            other => panic!("expected Illegal, got {other:?}"),
        }
        // a fitting p on the same platform works
        let ok = exec(&root, 4, |ctx, _| ctx.p(), Args::none()).unwrap();
        assert_eq!(ok, vec![4, 4, 4, 4]);
    }

    #[test]
    fn context_reports_its_topology() {
        let shared = exec(&root(), 2, |ctx, _| ctx.topology(), Args::none()).unwrap();
        assert_eq!(shared[0].name, "flat");
        assert_eq!(shared[0].levels, 1);
        let root = Root::new(Platform::hybrid(2)).with_max_procs(8);
        let hy = exec(&root, 4, |ctx, _| ctx.topology(), Args::none()).unwrap();
        assert_eq!(hy[0].name, "numa_pair");
        assert_eq!(hy[0].levels, 2);
        assert_eq!(hy[0].nodes, 2);
        assert_eq!(hy[0].procs_per_node, 2);
    }

    #[test]
    fn pod_bytes_roundtrip() {
        let v = [1.5f64, -2.25];
        let b = pod_bytes(&v);
        assert_eq!(b.len(), 16);
        assert_eq!(f64::from_le_bytes(b[0..8].try_into().unwrap()), 1.5);
    }
}
