//! Graph generators and MatrixMarket I/O.
//!
//! The paper's Table 4 uses cage15, uk-2002 and clueweb12 "all in
//! uncompressed MatrixMarket format". Those need up to 786 GB; per the
//! substitution rule we generate structurally similar graphs at RAM scale:
//! R-MAT/Kronecker scale-free graphs (web-crawl-like skew, the stress case
//! for shuffles) and banded "cage-like" matrices (DNA electrophoresis
//! graphs are near-banded with small bandwidth), and we keep the
//! MatrixMarket interchange so the pipeline matches the paper's.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::core::{LpfError, Result};
use crate::util::rng::XorShift64;

/// A directed graph / sparse matrix in COO form with unit weights.
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    /// Number of vertices (rows == cols == n).
    pub n: usize,
    /// Edges as (src, dst); may contain no duplicates (generators dedup).
    pub edges: Vec<(u32, u32)>,
}

impl Coo {
    /// Out-degree per vertex.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.n];
        for &(s, _) in &self.edges {
            d[s as usize] += 1;
        }
        d
    }

    /// Number of dangling vertices (out-degree zero) — the paper's LPF
    /// PageRank handles these where pure Spark does not.
    pub fn dangling_count(&self) -> usize {
        self.out_degrees().iter().filter(|&&d| d == 0).count()
    }
}

/// R-MAT (Kronecker) generator with the classic (a, b, c, d) quadrant
/// probabilities; defaults mirror Graph500: (0.57, 0.19, 0.19, 0.05).
pub struct RmatConfig {
    pub scale: u32,
    pub edge_factor: usize,
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub seed: u64,
}

impl RmatConfig {
    /// Graph500-style defaults for `2^scale` vertices.
    pub fn new(scale: u32, edge_factor: usize, seed: u64) -> Self {
        RmatConfig { scale, edge_factor, a: 0.57, b: 0.19, c: 0.19, seed }
    }
}

/// Streaming R-MAT edge generator: yields the same quadrant-descent edge
/// sequence `rmat` consumes, one edge at a time, without materialising the
/// edge list. Self-loops are skipped; duplicates are **kept** (multigraph
/// semantics — when out-degrees are counted over the same stream, column
/// sums of the PageRank matrix remain exactly 1, so iterating on the
/// multigraph is well-defined and needs no global dedup pass).
///
/// Cloning the iterator restarts the stream from the seed, which is how
/// two-pass consumers (degree count, then partition fill) re-read 2^20+
/// vertex graphs for free.
#[derive(Debug, Clone)]
pub struct RmatEdges {
    n: u32,
    remaining: usize,
    rng: XorShift64,
    a: f64,
    b: f64,
    c: f64,
}

impl RmatEdges {
    /// Number of vertices (`2^scale`).
    pub fn num_vertices(&self) -> usize {
        self.n as usize
    }
}

impl Iterator for RmatEdges {
    type Item = (u32, u32);

    fn next(&mut self) -> Option<(u32, u32)> {
        while self.remaining > 0 {
            self.remaining -= 1;
            let (mut lo_s, mut lo_d) = (0u32, 0u32);
            let mut span = self.n;
            while span > 1 {
                span /= 2;
                let r = self.rng.unit_f64();
                if r < self.a {
                    // top-left
                } else if r < self.a + self.b {
                    lo_d += span;
                } else if r < self.a + self.b + self.c {
                    lo_s += span;
                } else {
                    lo_s += span;
                    lo_d += span;
                }
            }
            if lo_s != lo_d {
                return Some((lo_s, lo_d));
            }
        }
        None
    }
}

/// Start a streaming R-MAT edge generator for `cfg`. Yields at most
/// `edge_factor · 2^scale` edges (self-loop draws are dropped).
pub fn rmat_edges(cfg: &RmatConfig) -> RmatEdges {
    RmatEdges {
        n: 1u32 << cfg.scale,
        remaining: cfg.edge_factor << cfg.scale,
        rng: XorShift64::new(cfg.seed),
        a: cfg.a,
        b: cfg.b,
        c: cfg.c,
    }
}

/// One streaming pass over `rmat_edges(cfg)`: per-vertex out-degrees and the
/// total edge count, without holding the edge list.
pub fn rmat_degrees(cfg: &RmatConfig) -> (Vec<u32>, usize) {
    let mut degs = vec![0u32; 1usize << cfg.scale];
    let mut m = 0usize;
    for (s, _) in rmat_edges(cfg) {
        degs[s as usize] += 1;
        m += 1;
    }
    (degs, m)
}

/// Generate an R-MAT graph: `2^scale` vertices, ~`edge_factor · n` edges
/// (deduplicated, self-loops removed).
pub fn rmat(cfg: &RmatConfig) -> Coo {
    let n = 1usize << cfg.scale;
    let mut edges: Vec<(u32, u32)> = rmat_edges(cfg).collect();
    edges.sort_unstable();
    edges.dedup();
    // deterministic shuffle so partitions are not degree-sorted
    let mut rng2 = XorShift64::new(cfg.seed ^ 0xD1CE);
    rng2.shuffle(&mut edges);
    Coo { n, edges }
}

/// Banded "cage-like" matrix: vertex i links to i±1..=band (wrapping),
/// similar in structure to the cage DNA-electrophoresis matrices
/// (near-banded, low skew, no dangling nodes).
pub fn cage_like(n: usize, band: usize, seed: u64) -> Coo {
    let mut rng = XorShift64::new(seed);
    let mut edges = Vec::with_capacity(n * band);
    for i in 0..n as u32 {
        for b in 1..=band {
            // keep most band edges, drop some randomly for irregularity
            if rng.unit_f64() < 0.9 {
                edges.push((i, (i + b as u32) % n as u32));
            }
            if rng.unit_f64() < 0.5 {
                edges.push((i, (i + n as u32 - b as u32) % n as u32));
            }
        }
    }
    edges.sort_unstable();
    edges.dedup();
    let mut rng2 = XorShift64::new(seed ^ 0xCA6E);
    rng2.shuffle(&mut edges);
    Coo { n, edges }
}

/// Write a COO graph as a MatrixMarket coordinate pattern file (1-based,
/// as the format requires).
pub fn write_matrix_market(coo: &Coo, path: &Path) -> Result<()> {
    let io_err = |e: std::io::Error| LpfError::Fatal(format!("mmio write: {e}"));
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(io_err)?;
    }
    let f = std::fs::File::create(path).map_err(io_err)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate pattern general").map_err(io_err)?;
    writeln!(w, "{} {} {}", coo.n, coo.n, coo.edges.len()).map_err(io_err)?;
    for &(s, d) in &coo.edges {
        writeln!(w, "{} {}", s + 1, d + 1).map_err(io_err)?;
    }
    Ok(())
}

/// Read a MatrixMarket coordinate file (pattern or real; weights dropped —
/// PageRank normalises anyway).
///
/// Real-world MatrixMarket dumps routinely carry duplicate entries and
/// self-loops; both would inflate `out_degrees` and skew the PageRank
/// column normalisation. The reader therefore canonicalises to what the
/// generators already produce: entries are deduplicated and self-loops
/// dropped. A vertex whose entries are *exclusively* self-loops is a
/// degenerate row — dropping its loops would silently convert it into a
/// dangling vertex the input never declared — so it is rejected with a
/// clean [`LpfError::Illegal`]. Indices are validated to be 1-based and in
/// range before conversion (a raw 0 index would wrap on `u32` subtraction).
pub fn read_matrix_market(path: &Path) -> Result<Coo> {
    let io_err = |e: std::io::Error| LpfError::Fatal(format!("mmio read: {e}"));
    let f = std::fs::File::open(path).map_err(io_err)?;
    let reader = std::io::BufReader::new(f);
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or_else(|| LpfError::Fatal("empty MatrixMarket file".into()))?
        .map_err(io_err)?;
    if !header.starts_with("%%MatrixMarket matrix coordinate") {
        return Err(LpfError::Fatal(format!("not a coordinate MatrixMarket file: {header}")));
    }
    let mut dims: Option<(usize, usize)> = None;
    let mut edges = Vec::new();
    let mut loop_rows: Vec<u32> = Vec::new();
    for line in lines {
        let line = line.map_err(io_err)?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        match dims {
            None => {
                let r: usize = it.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                    LpfError::Fatal("bad MatrixMarket size line".into())
                })?;
                let c: usize = it.next().and_then(|s| s.parse().ok()).ok_or_else(|| {
                    LpfError::Fatal("bad MatrixMarket size line".into())
                })?;
                dims = Some((r, c));
            }
            Some((r, c)) => {
                let s: u32 = it.next().and_then(|x| x.parse().ok()).ok_or_else(|| {
                    LpfError::Fatal("bad MatrixMarket entry".into())
                })?;
                let d: u32 = it.next().and_then(|x| x.parse().ok()).ok_or_else(|| {
                    LpfError::Fatal("bad MatrixMarket entry".into())
                })?;
                if s == 0 || d == 0 || s as usize > r || d as usize > c {
                    return Err(LpfError::Illegal(format!(
                        "MatrixMarket entry ({s}, {d}) outside 1-based {r}x{c} bounds"
                    )));
                }
                if s == d {
                    // self-loop: drop, but remember the row so a loop-only
                    // row can be rejected instead of silently going dangling
                    loop_rows.push(s - 1);
                } else {
                    edges.push((s - 1, d - 1));
                }
            }
        }
    }
    let (r, c) = dims.ok_or_else(|| LpfError::Fatal("MatrixMarket file has no size line".into()))?;
    edges.sort_unstable();
    edges.dedup();
    for &v in &loop_rows {
        let i = edges.partition_point(|&(s, _)| s < v);
        let has_real_out = i < edges.len() && edges[i].0 == v;
        if !has_real_out {
            return Err(LpfError::Illegal(format!(
                "vertex {} has only self-loop entries (degenerate row)",
                v + 1
            )));
        }
    }
    Ok(Coo { n: r.max(c), edges })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_is_deterministic_and_skewed() {
        let cfg = RmatConfig::new(10, 8, 7);
        let g1 = rmat(&cfg);
        let g2 = rmat(&cfg);
        assert_eq!(g1, g2);
        assert_eq!(g1.n, 1024);
        assert!(g1.edges.len() > 4 * g1.n, "dedup keeps most edges");
        // scale-free skew: max out-degree far above mean
        let degs = g1.out_degrees();
        let max = *degs.iter().max().unwrap() as f64;
        let mean = g1.edges.len() as f64 / g1.n as f64;
        assert!(max > 5.0 * mean, "max {max} vs mean {mean}");
        // R-MAT leaves some dangling vertices — PageRank must handle them
        assert!(g1.dangling_count() > 0);
    }

    #[test]
    fn rmat_has_no_self_loops_or_dups() {
        let g = rmat(&RmatConfig::new(8, 8, 3));
        let mut seen = std::collections::HashSet::new();
        for &(s, d) in &g.edges {
            assert_ne!(s, d);
            assert!(seen.insert((s, d)), "duplicate edge ({s},{d})");
        }
    }

    #[test]
    fn cage_like_is_low_skew_no_dangling() {
        let g = cage_like(512, 4, 1);
        assert_eq!(g.dangling_count(), 0);
        let degs = g.out_degrees();
        let max = *degs.iter().max().unwrap() as f64;
        let mean = g.edges.len() as f64 / g.n as f64;
        assert!(max < 3.0 * mean, "banded: low skew");
    }

    #[test]
    fn matrix_market_roundtrip() {
        let g = rmat(&RmatConfig::new(6, 4, 9));
        let path = std::env::temp_dir().join("lpf_mm_test.mtx");
        write_matrix_market(&g, &path).unwrap();
        let g2 = read_matrix_market(&path).unwrap();
        assert_eq!(g.n, g2.n);
        let mut a = g.edges.clone();
        let mut b = g2.edges.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn matrix_market_rejects_garbage() {
        let path = std::env::temp_dir().join("lpf_mm_bad.mtx");
        std::fs::write(&path, "hello\n1 2 3\n").unwrap();
        assert!(read_matrix_market(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn streaming_edges_match_batch_rmat() {
        let cfg = RmatConfig::new(9, 8, 11);
        let mut streamed: Vec<(u32, u32)> = rmat_edges(&cfg).collect();
        streamed.sort_unstable();
        streamed.dedup();
        let mut batch = rmat(&cfg).edges;
        batch.sort_unstable();
        assert_eq!(streamed, batch, "stream is rmat() before dedup+shuffle");
        // degrees from the stream count the multigraph, so they dominate
        // the deduplicated Coo degrees and sum to the stream length
        let (degs, m) = rmat_degrees(&cfg);
        assert_eq!(degs.iter().map(|&d| d as usize).sum::<usize>(), m);
        let coo_degs = rmat(&cfg).out_degrees();
        for v in 0..degs.len() {
            assert!(degs[v] >= coo_degs[v]);
        }
    }

    #[test]
    fn streaming_iterator_restarts_on_clone() {
        let cfg = RmatConfig::new(8, 4, 5);
        let it = rmat_edges(&cfg);
        let a: Vec<_> = it.clone().collect();
        let b: Vec<_> = it.collect();
        assert_eq!(a, b);
    }

    #[test]
    fn reader_dedups_and_drops_self_loops() {
        let path = std::env::temp_dir().join("lpf_mm_dups.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern general\n3 3 5\n1 2\n1 2\n2 2\n2 3\n3 1\n",
        )
        .unwrap();
        let g = read_matrix_market(&path).unwrap();
        let mut e = g.edges.clone();
        e.sort_unstable();
        assert_eq!(e, vec![(0, 1), (1, 2), (2, 0)]);
        assert_eq!(g.out_degrees(), vec![1, 1, 1], "dups and loops not counted");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn reader_rejects_self_loop_only_row() {
        let path = std::env::temp_dir().join("lpf_mm_loop_only.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern general\n3 3 3\n1 2\n2 2\n3 1\n",
        )
        .unwrap();
        let err = read_matrix_market(&path).unwrap_err();
        assert!(matches!(err, LpfError::Illegal(_)), "got {err:?}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn reader_rejects_out_of_range_indices() {
        let path = std::env::temp_dir().join("lpf_mm_oob.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern general\n3 3 1\n0 2\n",
        )
        .unwrap();
        assert!(matches!(read_matrix_market(&path).unwrap_err(), LpfError::Illegal(_)));
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate pattern general\n3 3 1\n1 4\n",
        )
        .unwrap();
        assert!(matches!(read_matrix_market(&path).unwrap_err(), LpfError::Illegal(_)));
        std::fs::remove_file(path).ok();
    }
}
