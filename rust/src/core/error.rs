//! LPF error model.
//!
//! The paper (§2.1) distinguishes *user-mitigable* errors — such as
//! out-of-memory conditions, which are guaranteed to have no side effects —
//! from *fatal* errors. LPF maintains only **local** error state; a global
//! state would require costly periodic inter-process interaction. Only
//! `lpf_sync`, `lpf_exec`, `lpf_hook` and `lpf_rehook` may fail fatally due
//! to *remote* errors, at the latest when attempting to communicate with an
//! aborted LPF process.

use std::fmt;

/// Errors returned by LPF primitives.
///
/// Mitigable errors (`OutOfMemory`, `SlotCapacity`, `QueueCapacity`) are
/// guaranteed to leave the context unchanged: the offending operation is not
/// partially applied and the program may retry after raising capacities.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LpfError {
    /// Heap memory for buffers could not be reserved. Mitigable.
    OutOfMemory(String),
    /// The memory-slot register is full; raise it with
    /// [`resize_memory_register`](crate::ctx::Context::resize_memory_register).
    /// Mitigable, no side effects.
    SlotCapacity { capacity: usize, in_use: usize },
    /// The message queue is full; raise it with
    /// [`resize_message_queue`](crate::ctx::Context::resize_message_queue).
    /// Mitigable, no side effects.
    QueueCapacity { capacity: usize },
    /// An argument violated a documented precondition (e.g. out-of-range
    /// offset, unknown slot, write overlapping a read). These indicate
    /// program bugs; LPF detects what it can cheaply and in checked builds.
    Illegal(String),
    /// A peer process aborted; the context is unusable. Fatal. Observed only
    /// by `sync`, `exec`, `hook`, and `rehook`, as the paper prescribes.
    PeerAborted { pid: u32 },
    /// Unrecoverable internal failure (transport torn down, poisoned state).
    Fatal(String),
}

impl fmt::Display for LpfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LpfError::OutOfMemory(what) => write!(f, "out of memory: {what}"),
            LpfError::SlotCapacity { capacity, in_use } => {
                write!(f, "memory register full: capacity {capacity}, in use {in_use}")
            }
            LpfError::QueueCapacity { capacity } => {
                write!(f, "message queue full: capacity {capacity} messages")
            }
            LpfError::Illegal(what) => write!(f, "illegal argument: {what}"),
            LpfError::PeerAborted { pid } => {
                write!(f, "fatal: peer {pid} aborted the context")
            }
            LpfError::Fatal(what) => write!(f, "fatal: {what}"),
        }
    }
}

impl std::error::Error for LpfError {}

impl LpfError {
    /// True for errors the paper classifies as user-mitigable: the call had
    /// no side effects and the program may continue in the same context.
    pub fn is_mitigable(&self) -> bool {
        matches!(
            self,
            LpfError::OutOfMemory(_)
                | LpfError::SlotCapacity { .. }
                | LpfError::QueueCapacity { .. }
        )
    }
}

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, LpfError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mitigable_classification_matches_paper() {
        assert!(LpfError::OutOfMemory("x".into()).is_mitigable());
        assert!(LpfError::SlotCapacity { capacity: 1, in_use: 1 }.is_mitigable());
        assert!(LpfError::QueueCapacity { capacity: 0 }.is_mitigable());
        assert!(!LpfError::PeerAborted { pid: 3 }.is_mitigable());
        assert!(!LpfError::Fatal("x".into()).is_mitigable());
        assert!(!LpfError::Illegal("x".into()).is_mitigable());
    }

    #[test]
    fn display_is_informative() {
        let e = LpfError::SlotCapacity { capacity: 4, in_use: 4 };
        assert!(e.to_string().contains("capacity 4"));
    }
}
