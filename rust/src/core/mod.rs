//! Core LPF types: process ids, memory-slot handles, SPMD arguments, and
//! machine parameters (the BSP triple `(p, g, ℓ)` exposed by `lpf_probe`).

pub mod error;
pub mod machine;

pub use error::{LpfError, Result};
pub use machine::MachineParams;

/// Process identifier within a context: `0 <= s < p`, as in the paper.
pub type Pid = u32;

/// Maximum parallelism request for [`exec`](crate::ctx::exec): "use all
/// available processes". Mirrors `LPF_MAX_P`.
pub const MAX_P: Pid = Pid::MAX;

/// Which register a slot lives in.
///
/// `lpf_register_local` creates slots only ever referred to by the local
/// process; `lpf_register_global` is collective and produces slots whose ids
/// align across all processes of the context, so they can name *remote*
/// memory in `put`/`get`. Keeping the two id spaces separate lets local
/// registrations proceed without any collective coordination (O(1), paper
/// Fig. 1) while preserving global id alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SlotKind {
    /// Registered via `register_local`; valid only on the owning process.
    Local,
    /// Registered via the collective `register_global`; the same id denotes
    /// the "same" (per-process) area on every process.
    Global,
}

/// A memory-slot handle (`lpf_memslot_t`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Memslot {
    pub(crate) kind: SlotKind,
    pub(crate) index: u32,
    /// Epoch guard: slots from a deregistered generation are rejected in
    /// checked builds.
    pub(crate) gen: u32,
}

impl Memslot {
    /// Which register this slot lives in.
    pub fn kind(&self) -> SlotKind {
        self.kind
    }
    /// Index within its register (diagnostic; stable until deregistered).
    pub fn index(&self) -> u32 {
        self.index
    }
}

/// SPMD arguments (`lpf_args_t`): an input broadcast to every process and a
/// per-process output collected by `exec`/`hook`/`rehook`.
///
/// The C API passes raw byte buffers plus an optional symbol table; in Rust
/// we use owned bytes. Typed wrappers live in [`crate::ctx`].
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Input payload, broadcast to all processes (may be empty, cf.
    /// `LPF_NO_ARGS`).
    pub input: Vec<u8>,
}

impl Args {
    /// No arguments — mirrors `LPF_NO_ARGS`.
    pub const fn none() -> Self {
        Args { input: Vec::new() }
    }

    /// Wrap an input payload.
    pub fn input(bytes: impl Into<Vec<u8>>) -> Self {
        Args { input: bytes.into() }
    }
}

/// Attributes to `put`/`get` (`lpf_msg_attr_t`). The core defines only the
/// default; extensions may relax guarantees (paper §2.1).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MsgAttr {
    /// Promise that this message does not overlap any other write. An
    /// implementation may then skip conflict resolution for it.
    pub no_conflict: bool,
}

/// `LPF_MSG_DEFAULT`.
pub const MSG_DEFAULT: MsgAttr = MsgAttr { no_conflict: false };

/// Attributes to `sync` (`lpf_sync_attr_t`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SyncAttr {
    /// Caller asserts the whole superstep is free of write conflicts;
    /// the engine may skip the conflict-resolution phase, lowering the
    /// effective `g` (paper §2.1 names exactly this optimisation).
    pub assume_no_conflicts: bool,
}

/// `LPF_SYNC_DEFAULT`.
pub const SYNC_DEFAULT: SyncAttr = SyncAttr { assume_no_conflicts: false };

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_none_is_empty() {
        assert!(Args::none().input.is_empty());
    }

    #[test]
    fn args_input_roundtrip() {
        let a = Args::input(vec![1u8, 2, 3]);
        assert_eq!(a.input, vec![1, 2, 3]);
    }

    #[test]
    fn memslot_accessors() {
        let m = Memslot { kind: SlotKind::Global, index: 7, gen: 0 };
        assert_eq!(m.kind(), SlotKind::Global);
        assert_eq!(m.index(), 7);
    }

    #[test]
    fn default_attrs_are_strict() {
        assert!(!MSG_DEFAULT.no_conflict);
        assert!(!SYNC_DEFAULT.assume_no_conflicts);
    }
}
