//! BSP machine parameters, as returned by `lpf_probe`.
//!
//! The paper (§2.2) requires `lpf_probe` to run in Ω(1); implementations may
//! use an offline benchmark to fill a Θ(1) lookup table (as we do — see
//! [`crate::probe`]), or benchmark online at arbitrary cost.

/// The BSP triple for one word size: `T(h) = g·h + ℓ`.
///
/// Units follow the paper's Table 3: `g` is in time-units per *word* (the
/// word size `w` in bytes is part of the record), `ℓ` in time-units.
/// Internally we keep nanoseconds; the Table-3 printer normalises by the
/// measured memcpy speed `r`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BspParams {
    /// Word size in bytes this record was measured at.
    pub word_bytes: usize,
    /// Per-word throughput cost, ns/word.
    pub g_ns: f64,
    /// Latency / synchronisation cost, ns.
    pub l_ns: f64,
}

/// Everything `lpf_probe` reports about the machine underneath a context.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineParams {
    /// Number of processes in the probed context.
    pub p: u32,
    /// Upper bound on processes a fresh `exec` could obtain.
    pub free_p: u32,
    /// `(g, ℓ)` per word size, ascending by `word_bytes`. Non-empty.
    pub params: Vec<BspParams>,
    /// Measured memcpy speed `r` in ns/byte (Table 3 normaliser).
    pub r_ns_per_byte: f64,
}

impl MachineParams {
    /// Fallback used before any offline probe data exists: conservative
    /// constants so algorithm parametrisation still functions.
    pub fn conservative(p: u32) -> Self {
        MachineParams {
            p,
            free_p: p,
            params: vec![BspParams { word_bytes: 8, g_ns: 10.0, l_ns: 10_000.0 }],
            r_ns_per_byte: 1.0,
        }
    }

    /// `(g, ℓ)` in ns for a message granularity of `word_bytes`, picking the
    /// closest measured word size at or below the request (Θ(1)–Θ(#records)
    /// with #records a small constant — table lookup, per the paper).
    pub fn at_word(&self, word_bytes: usize) -> BspParams {
        let mut best = self.params[0];
        for rec in &self.params {
            if rec.word_bytes <= word_bytes {
                best = *rec;
            }
        }
        best
    }

    /// Predicted time in ns to execute an `h`-relation of `h` words of size
    /// `word_bytes`: the model-compliance contract `T(h) = g·h + ℓ`.
    pub fn h_relation_ns(&self, h: usize, word_bytes: usize) -> f64 {
        let BspParams { g_ns, l_ns, .. } = self.at_word(word_bytes);
        g_ns * h as f64 + l_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mp() -> MachineParams {
        MachineParams {
            p: 4,
            free_p: 4,
            params: vec![
                BspParams { word_bytes: 8, g_ns: 100.0, l_ns: 5000.0 },
                BspParams { word_bytes: 1024, g_ns: 10.0, l_ns: 5000.0 },
            ],
            r_ns_per_byte: 0.8,
        }
    }

    #[test]
    fn at_word_picks_floor_record() {
        assert_eq!(mp().at_word(8).g_ns, 100.0);
        assert_eq!(mp().at_word(512).g_ns, 100.0);
        assert_eq!(mp().at_word(1024).g_ns, 10.0);
        assert_eq!(mp().at_word(1 << 20).g_ns, 10.0);
    }

    #[test]
    fn h_relation_is_affine() {
        let m = mp();
        let t0 = m.h_relation_ns(0, 8);
        let t1 = m.h_relation_ns(1, 8);
        let t2 = m.h_relation_ns(2, 8);
        assert_eq!(t0, 5000.0);
        assert!((t2 - t1 - (t1 - t0)).abs() < 1e-9, "affine in h");
    }

    #[test]
    fn conservative_is_usable() {
        let m = MachineParams::conservative(3);
        assert_eq!(m.p, 3);
        assert!(m.h_relation_ns(10, 8) > 0.0);
    }
}
