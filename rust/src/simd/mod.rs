//! Portable explicit-width f32 lanes for the hot kernels.
//!
//! The FFT butterflies (`fft::local`) and the collectives folds
//! (`collectives`) spend their time in elementwise f32 arithmetic over
//! contiguous runs. Rather than trust the autovectoriser to find the
//! vector shape through the surrounding index algebra, the hot sweeps are
//! written against **explicit-width lane structs**: a [`Lanes<W>`] is a
//! `[f32; W]` wrapper whose `+`/`-`/`*`/`min`/`max` are straight-line
//! elementwise loops. Fixed-width array arithmetic with no
//! loop-carried dependence is the one shape every backend's
//! autovectoriser compiles to full-width vector instructions (SSE/NEON at
//! `W = 4`, AVX at `W = 8`, and clean scalar code on targets with
//! neither) — so this stays `std`-only and portable: no `std::simd`, no
//! intrinsics, no feature detection.
//!
//! Lane width is **selected at plan time**, not per call: an [`FftPlan`]
//! carries the [`Lane`] choice for its size ([`Lane::for_len`]) and the
//! kernels dispatch on it once per stage, outside the sweeps. The scalar
//! kernels remain compiled and reachable ([`Lane::Scalar`]) as the
//! correctness oracle: the lane sweeps perform *identical arithmetic per
//! element* (same operations, same order, no reassociation and no FMA
//! contraction), so lane and scalar results are pinned **bit-identical**
//! by the kernel tests — vectorisation here changes throughput, never
//! results.
//!
//! [`FftPlan`]: crate::fft::FftPlan

use std::ops::{Add, Mul, Sub};

/// A `W`-wide f32 lane: elementwise arithmetic over a fixed-size array.
#[derive(Debug, Clone, Copy, PartialEq)]
#[repr(transparent)]
pub struct Lanes<const W: usize>(pub [f32; W]);

/// Four f32 lanes — one SSE/NEON register.
pub type F32x4 = Lanes<4>;
/// Eight f32 lanes — one AVX register (two SSE/NEON ops where absent).
pub type F32x8 = Lanes<8>;

impl<const W: usize> Lanes<W> {
    /// All lanes set to `x`.
    #[inline(always)]
    pub fn splat(x: f32) -> Self {
        Lanes([x; W])
    }

    /// Load the first `W` elements of `s` (panics if `s` is shorter).
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        let mut a = [0f32; W];
        a.copy_from_slice(&s[..W]);
        Lanes(a)
    }

    /// Store into the first `W` elements of `s`.
    #[inline(always)]
    pub fn store(self, s: &mut [f32]) {
        s[..W].copy_from_slice(&self.0);
    }

    /// Load `W` elements starting at `s[i]` without bounds checks.
    ///
    /// # Safety
    /// `i + W <= s.len()`.
    #[inline(always)]
    pub unsafe fn load_unchecked(s: &[f32], i: usize) -> Self {
        debug_assert!(i + W <= s.len());
        // `[f32; W]` is 4-byte aligned; read_unaligned keeps this valid
        // for any slice offset and compiles to an unaligned vector load.
        Lanes((s.as_ptr().add(i) as *const [f32; W]).read_unaligned())
    }

    /// Store `W` elements starting at `s[i]` without bounds checks.
    ///
    /// # Safety
    /// `i + W <= s.len()`.
    #[inline(always)]
    pub unsafe fn store_unchecked(self, s: &mut [f32], i: usize) {
        debug_assert!(i + W <= s.len());
        (s.as_mut_ptr().add(i) as *mut [f32; W]).write_unaligned(self.0);
    }

    /// Elementwise maximum (IEEE `f32::max` per lane, like the scalar
    /// oracle).
    #[inline(always)]
    pub fn max(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a = a.max(*b);
        }
        Lanes(r)
    }

    /// Elementwise minimum.
    #[inline(always)]
    pub fn min(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a = a.min(*b);
        }
        Lanes(r)
    }
}

impl<const W: usize> Add for Lanes<W> {
    type Output = Self;
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a += *b;
        }
        Lanes(r)
    }
}

impl<const W: usize> Sub for Lanes<W> {
    type Output = Self;
    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a -= *b;
        }
        Lanes(r)
    }
}

impl<const W: usize> Mul for Lanes<W> {
    type Output = Self;
    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(&o.0) {
            *a *= *b;
        }
        Lanes(r)
    }
}

/// Lane-width choice for a kernel, made once at plan time.
///
/// The FFT stages require the vectorised dimension (butterfly index `k`
/// for single transforms, batch index `t` for batched ones) to cover at
/// least one lane; each stage falls back to the scalar sweep when its own
/// extent is narrower, so a wide `Lane` choice is always safe — it is a
/// *ceiling*, not a promise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// The scalar oracle kernels.
    Scalar,
    /// 4-wide lanes.
    X4,
    /// 8-wide lanes.
    X8,
}

impl Lane {
    /// Preferred lane ceiling for a problem of `n` elements: 8-wide when a
    /// full lane fits, narrowing for tiny sizes where lane setup is pure
    /// overhead.
    pub fn for_len(n: usize) -> Lane {
        if n >= 8 {
            Lane::X8
        } else if n >= 4 {
            Lane::X4
        } else {
            Lane::Scalar
        }
    }

    /// The width in f32 elements (1 for scalar).
    pub fn width(self) -> usize {
        match self {
            Lane::Scalar => 1,
            Lane::X4 => 4,
            Lane::X8 => 8,
        }
    }
}

/// The f32 fold operators the collectives accelerate. The scalar oracle
/// is the same expression per element (`a + b`, `f32::max`, `f32::min`),
/// so lane and scalar folds agree bitwise, NaN semantics included.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FloatOp {
    /// Elementwise sum.
    Sum,
    /// Elementwise IEEE maximum.
    Max,
    /// Elementwise IEEE minimum.
    Min,
}

impl FloatOp {
    /// The scalar fold step.
    #[inline(always)]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            FloatOp::Sum => a + b,
            FloatOp::Max => a.max(b),
            FloatOp::Min => a.min(b),
        }
    }

    #[inline(always)]
    fn combine<const W: usize>(self, a: Lanes<W>, b: Lanes<W>) -> Lanes<W> {
        match self {
            FloatOp::Sum => a + b,
            FloatOp::Max => a.max(b),
            FloatOp::Min => a.min(b),
        }
    }
}

/// `acc[i] = op(acc[i], other[i])` over the common length: 8-wide main
/// loop, 4-wide step-down, scalar tail. This is the collectives' fold
/// inner loop (`reduce`/`allreduce`/`scan` accumulate one peer
/// contribution per call); lane order equals scalar order, so results are
/// bit-identical to the scalar oracle.
pub fn fold_f32(acc: &mut [f32], other: &[f32], op: FloatOp) {
    let n = acc.len().min(other.len());
    let mut i = 0usize;
    while i + 8 <= n {
        // SAFETY: i + 8 <= n <= both lengths.
        unsafe {
            op.combine(F32x8::load_unchecked(acc, i), F32x8::load_unchecked(other, i))
                .store_unchecked(acc, i);
        }
        i += 8;
    }
    while i + 4 <= n {
        // SAFETY: i + 4 <= n <= both lengths.
        unsafe {
            op.combine(F32x4::load_unchecked(acc, i), F32x4::load_unchecked(other, i))
                .store_unchecked(acc, i);
        }
        i += 4;
    }
    while i < n {
        acc[i] = op.apply(acc[i], other[i]);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_arithmetic_is_elementwise() {
        let a = F32x4::load(&[1.0, 2.0, 3.0, 4.0]);
        let b = F32x4::splat(2.0);
        assert_eq!((a + b).0, [3.0, 4.0, 5.0, 6.0]);
        assert_eq!((a - b).0, [-1.0, 0.0, 1.0, 2.0]);
        assert_eq!((a * b).0, [2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.max(b).0, [2.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.min(b).0, [1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn unchecked_load_store_roundtrip_at_odd_offsets() {
        let src: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut dst = vec![0f32; 20];
        for off in [0usize, 1, 3, 7, 12] {
            // SAFETY: off + 8 <= 20.
            unsafe { F32x8::load_unchecked(&src, off).store_unchecked(&mut dst, off) };
            assert_eq!(&dst[off..off + 8], &src[off..off + 8]);
        }
    }

    #[test]
    fn plan_time_selection_narrows_with_size() {
        assert_eq!(Lane::for_len(1 << 20), Lane::X8);
        assert_eq!(Lane::for_len(8), Lane::X8);
        assert_eq!(Lane::for_len(4), Lane::X4);
        assert_eq!(Lane::for_len(2), Lane::Scalar);
        assert_eq!(Lane::Scalar.width(), 1);
        assert_eq!(Lane::X4.width(), 4);
        assert_eq!(Lane::X8.width(), 8);
    }

    #[test]
    fn fold_matches_scalar_bitwise_at_awkward_lengths() {
        for len in [0usize, 1, 3, 4, 5, 7, 8, 9, 12, 13, 31, 64, 65] {
            for op in [FloatOp::Sum, FloatOp::Max, FloatOp::Min] {
                let a: Vec<f32> = (0..len).map(|i| (i as f32).sin() * 3.0).collect();
                let b: Vec<f32> = (0..len).map(|i| (i as f32).cos() * 2.0).collect();
                let mut lane = a.clone();
                fold_f32(&mut lane, &b, op);
                let scalar: Vec<f32> =
                    a.iter().zip(&b).map(|(&x, &y)| op.apply(x, y)).collect();
                for (l, s) in lane.iter().zip(&scalar) {
                    assert_eq!(l.to_bits(), s.to_bits(), "len {len} op {op:?}");
                }
            }
        }
    }

    #[test]
    fn fold_preserves_ieee_nan_semantics_of_the_oracle() {
        let mut acc = vec![f32::NAN; 9];
        let other = vec![1.0f32; 9];
        fold_f32(&mut acc, &other, FloatOp::Max);
        // f32::max(NAN, 1.0) == 1.0 — the lane path must agree
        assert!(acc.iter().all(|&x| x == 1.0));
    }
}
