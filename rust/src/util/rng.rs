//! Deterministic PRNGs used by the randomised Bruck router, the graph
//! generators, and the in-repo property-testing helper. (The offline crate
//! set has no `rand`; `xorshift*` is adequate and reproducible.)

/// xorshift64* — fast, full-period 2^64−1, passes BigCrush small-set.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Seeded constructor; a zero seed is remapped (xorshift requires ≠ 0).
    pub fn new(seed: u64) -> Self {
        XorShift64 { state: if seed == 0 { 0x9e3779b97f4a7c15 } else { seed } }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // bias is < 2^-32 for our bounds (≤ 2^32).
        ((self.next_u64() >> 32).wrapping_mul(bound)) >> 32
    }

    /// Uniform usize in `[0, bound)`.
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift64::new(123);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn unit_f64_in_range_and_varied() {
        let mut r = XorShift64::new(5);
        let xs: Vec<f64> = (0..1000).map(|_| r.unit_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order differs whp");
    }
}
