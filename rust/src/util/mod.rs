//! Small self-contained utilities (the offline crate set is minimal).
pub mod radix;
pub mod rng;

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of OS threads created through [`spawn_counted`].
/// All crate-internal non-scoped thread creation (the pool's workers, and
/// therefore every `exec`) goes through the counted wrapper, so
/// `bench_exec --smoke` can assert that a warm-pool job dispatch spawns
/// zero threads.
static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

/// Spawn a thread, counting it in [`thread_spawn_count`].
pub fn spawn_counted<F, T>(f: F) -> std::thread::JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    THREAD_SPAWNS.fetch_add(1, Ordering::Relaxed);
    std::thread::spawn(f)
}

/// Number of threads spawned so far via [`spawn_counted`] (monotonic;
/// benches read a before/after delta).
pub fn thread_spawn_count() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

/// Pads and aligns a value to 128 bytes so that neighbouring values in an
/// array never share a cache line (two 64-byte lines on x86 prefetch
/// pairs). Stand-in for `crossbeam_utils::CachePadded` — the build is
/// dependency-free.
#[derive(Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` in its own cache line.
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    /// Unwrap.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

#[cfg(test)]
mod tests {
    use super::CachePadded;

    #[test]
    fn cache_padded_is_line_aligned_and_transparent() {
        assert!(std::mem::align_of::<CachePadded<u64>>() >= 128);
        let c = CachePadded::new(7u64);
        assert_eq!(*c, 7);
        assert_eq!(c.into_inner(), 7);
        let v: Vec<CachePadded<u8>> = (0..3).map(CachePadded::new).collect();
        let a = &*v[0] as *const u8 as usize;
        let b = &*v[1] as *const u8 as usize;
        assert!(b - a >= 128, "neighbours must not share a line");
    }
}
