//! Small self-contained utilities (the offline crate set is minimal).
pub mod radix;
pub mod rng;

/// Pads and aligns a value to 128 bytes so that neighbouring values in an
/// array never share a cache line (two 64-byte lines on x86 prefetch
/// pairs). Stand-in for `crossbeam_utils::CachePadded` — the build is
/// dependency-free.
#[derive(Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` in its own cache line.
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    /// Unwrap.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

#[cfg(test)]
mod tests {
    use super::CachePadded;

    #[test]
    fn cache_padded_is_line_aligned_and_transparent() {
        assert!(std::mem::align_of::<CachePadded<u64>>() >= 128);
        let c = CachePadded::new(7u64);
        assert_eq!(*c, 7);
        assert_eq!(c.into_inner(), 7);
        let v: Vec<CachePadded<u8>> = (0..3).map(CachePadded::new).collect();
        let a = &*v[0] as *const u8 as usize;
        let b = &*v[1] as *const u8 as usize;
        assert!(b - a >= 128, "neighbours must not share a line");
    }
}
