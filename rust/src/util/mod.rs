//! Small self-contained utilities (the offline crate set is minimal).
pub mod radix;
pub mod rng;
