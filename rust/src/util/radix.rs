//! LSB radix sort on `u64` keys.
//!
//! The paper's write-conflict resolution runs in `O(m + h_s + R/s)` memory
//! and `O(m + h_s + h_b/s)` time using a radix sort at the destination
//! (Table 1). A comparison sort would put a `log m` factor into the `lpf_sync`
//! critical path and break the stated bound, so we radix-sort descriptor
//! keys here: 8-bit digits, early exit on already-uniform digits.

/// Sort `items` ascending and stably by `key(item)`: the convenience form
/// of [`radix_sort_idx_by_key`] (sorts an index permutation, then applies
/// it — one radix core, two entry points).
pub fn radix_sort_by_key<T, F: Fn(&T) -> u64>(items: &mut Vec<T>, key: F) {
    let n = items.len();
    if n <= 1 {
        return;
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let mut scratch = Vec::new();
    radix_sort_idx_by_key(&mut idx, &mut scratch, |i| key(&items[i as usize]));
    // Apply the permutation.
    let mut taken: Vec<Option<T>> = items.drain(..).map(Some).collect();
    items.extend(
        idx.iter().map(|&i| taken[i as usize].take().expect("permutation is a bijection")),
    );
}

/// Stably sort the index vector `idx` ascending by `key(i)`, reusing
/// `scratch` as the ping-pong buffer (LSB radix, 8-bit digits, early exit
/// on uniform digits).
///
/// Allocation-free once `scratch` has grown to `idx.len()`: this is the
/// variant the sync engine threads its per-process scratch through so the
/// steady-state superstep never touches the heap.
pub fn radix_sort_idx_by_key(
    idx: &mut Vec<u32>,
    scratch: &mut Vec<u32>,
    key: impl Fn(u32) -> u64,
) {
    let n = idx.len();
    if n <= 1 {
        return;
    }
    let mut max_key = 0u64;
    for &i in idx.iter() {
        max_key |= key(i);
    }
    let passes = ((64 - max_key.leading_zeros() as usize) + 7) / 8;
    scratch.clear();
    scratch.resize(n, 0);
    let mut counts = [0usize; 256];
    for pass in 0..passes {
        let shift = pass * 8;
        counts.fill(0);
        for &i in idx.iter() {
            counts[((key(i) >> shift) & 0xff) as usize] += 1;
        }
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        let mut sum = 0usize;
        for c in counts.iter_mut() {
            let t = *c;
            *c = sum;
            sum += t;
        }
        for &i in idx.iter() {
            let d = ((key(i) >> shift) & 0xff) as usize;
            scratch[counts[d]] = i;
            counts[d] += 1;
        }
        std::mem::swap(idx, scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn sorts_random_u64() {
        let mut rng = XorShift64::new(42);
        let mut v: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
        let mut expect = v.clone();
        expect.sort_unstable();
        radix_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, expect);
    }

    #[test]
    fn stable_on_equal_keys() {
        // (key, original position); equal keys must keep original order.
        let mut v: Vec<(u64, usize)> = vec![(5, 0), (1, 1), (5, 2), (1, 3), (5, 4)];
        radix_sort_by_key(&mut v, |&(k, _)| k);
        assert_eq!(v, vec![(1, 1), (1, 3), (5, 0), (5, 2), (5, 4)]);
    }

    #[test]
    fn handles_empty_and_single() {
        let mut v: Vec<u64> = vec![];
        radix_sort_by_key(&mut v, |&x| x);
        assert!(v.is_empty());
        let mut v = vec![9u64];
        radix_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, vec![9]);
    }

    #[test]
    fn high_bit_keys() {
        let mut v = vec![u64::MAX, 0, 1 << 63, 42];
        radix_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, vec![0, 42, 1 << 63, u64::MAX]);
    }

    #[test]
    fn idx_sort_matches_stable_sort_and_reuses_scratch() {
        let mut rng = XorShift64::new(7);
        let mut scratch = Vec::new();
        for _ in 0..50 {
            let n = rng.below_usize(40);
            let keys: Vec<u64> = (0..n).map(|_| rng.next_u64() & 0x3FF).collect();
            let mut idx: Vec<u32> = (0..n as u32).collect();
            radix_sort_idx_by_key(&mut idx, &mut scratch, |i| keys[i as usize]);
            let mut expect: Vec<u32> = (0..n as u32).collect();
            expect.sort_by_key(|&i| keys[i as usize]); // stable
            assert_eq!(idx, expect);
        }
    }
}
