//! LSB radix sort on `u64` keys.
//!
//! The paper's write-conflict resolution runs in `O(m + h_s + R/s)` memory
//! and `O(m + h_s + h_b/s)` time using a radix sort at the destination
//! (Table 1). A comparison sort would put a `log m` factor into the `lpf_sync`
//! critical path and break the stated bound, so we radix-sort descriptor
//! keys here: 8-bit digits, early exit on already-uniform digits.

/// Sort `items` ascending and stably by `key(item)`.
///
/// O(passes · n) time, O(n) scratch. Stability matters: the conflict
/// resolver relies on stable order for deterministic CRCW winners.
pub fn radix_sort_by_key<T, F: Fn(&T) -> u64>(items: &mut Vec<T>, key: F) {
    let n = items.len();
    if n <= 1 {
        return;
    }
    // Small inputs: insertion-style via stable std sort on the key is not
    // allowed (comparison); but a 2-pass counting sort on tiny n costs more
    // than it saves only below ~8 elements, where cost is negligible anyway.
    let mut max_key = 0u64;
    for it in items.iter() {
        max_key |= key(it);
    }
    let passes = ((64 - max_key.leading_zeros() as usize) + 7) / 8;
    let mut src: Vec<(u64, usize)> = items.iter().enumerate().map(|(i, t)| (key(t), i)).collect();
    let mut dst: Vec<(u64, usize)> = vec![(0, 0); n];
    let mut counts = [0usize; 256];
    for pass in 0..passes {
        let shift = pass * 8;
        counts.fill(0);
        for &(k, _) in src.iter() {
            counts[((k >> shift) & 0xff) as usize] += 1;
        }
        // skip pass if all keys share this digit
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        let mut sum = 0usize;
        for c in counts.iter_mut() {
            let t = *c;
            *c = sum;
            sum += t;
        }
        for &(k, i) in src.iter() {
            let d = ((k >> shift) & 0xff) as usize;
            dst[counts[d]] = (k, i);
            counts[d] += 1;
        }
        std::mem::swap(&mut src, &mut dst);
    }
    // Apply the permutation.
    let mut out = Vec::with_capacity(n);
    let mut taken: Vec<Option<T>> = items.drain(..).map(Some).collect();
    for &(_, i) in src.iter() {
        out.push(taken[i].take().expect("permutation is a bijection"));
    }
    *items = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64;

    #[test]
    fn sorts_random_u64() {
        let mut rng = XorShift64::new(42);
        let mut v: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
        let mut expect = v.clone();
        expect.sort_unstable();
        radix_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, expect);
    }

    #[test]
    fn stable_on_equal_keys() {
        // (key, original position); equal keys must keep original order.
        let mut v: Vec<(u64, usize)> = vec![(5, 0), (1, 1), (5, 2), (1, 3), (5, 4)];
        radix_sort_by_key(&mut v, |&(k, _)| k);
        assert_eq!(v, vec![(1, 1), (1, 3), (5, 0), (5, 2), (5, 4)]);
    }

    #[test]
    fn handles_empty_and_single() {
        let mut v: Vec<u64> = vec![];
        radix_sort_by_key(&mut v, |&x| x);
        assert!(v.is_empty());
        let mut v = vec![9u64];
        radix_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, vec![9]);
    }

    #[test]
    fn high_bit_keys() {
        let mut v = vec![u64::MAX, 0, 1 << 63, 42];
        radix_sort_by_key(&mut v, |&x| x);
        assert_eq!(v, vec![0, 42, 1 << 63, u64::MAX]);
    }
}
