//! The two PageRanks of Table 4.
//!
//! * [`pure_spark_pagerank`] — the canonical Spark PageRank the paper
//!   compares against (its footnote 1 points at Spark's bundled
//!   `SparkPageRank` example): `join → flatMap → reduceByKey → mapValues`,
//!   **no dangling-node handling, no convergence check**, checkpoint every
//!   ten iterations to break lineages. The paper keeps it "as is" because
//!   it can only skew the comparison in Spark's favour; so do we.
//! * [`accelerated_pagerank`] — the LPF PageRank invoked *from the
//!   sparksim workers* via the paper's §4.3 bootstrap: collect worker
//!   hostnames → dedupe → broadcast → each worker derives `(p, s, master)`
//!   → `Init::over_master` → `hook`, with direct access to the worker-side
//!   data. No sparksim internals change — exactly the paper's claim.

use std::sync::Arc;
use std::time::Duration;

use super::rdd::{Rdd, Spark};
use crate::core::{Args, SYNC_DEFAULT};
use crate::ctx::{hook, Init, Platform};
use crate::graphblas::{partition, Compute, DistPageRank, PrOutcome};
use crate::graphgen::Coo;

/// Pure-Spark PageRank: `n_iters` canonical iterations; returns the final
/// (vertex, rank) pairs. Ranks follow the canonical `0.15 + 0.85·x`
/// formulation (summing to ≈ n, not 1 — as in Spark's own example).
pub fn pure_spark_pagerank(
    sc: &Spark,
    links_input: &[(u32, u32)],
    n_iters: u32,
    checkpoint_every: u32,
) -> Vec<(u32, f64)> {
    // adjacency lists: groupByKey as reduceByKey over Vec concat
    let links: Rdd<(u32, Vec<u32>)> = sc
        .parallelize(links_input.to_vec(), sc.default_parallelism)
        .map(|&(s, d)| (s, vec![d]))
        .reduce_by_key(|mut a, mut b| {
            a.append(&mut b);
            a
        })
        .checkpoint(); // Spark caches the link structure
    let mut ranks: Rdd<(u32, f64)> = links.map_values(|_| 1.0);
    for it in 1..=n_iters {
        let contribs = links.join(&ranks).flat_map(|(_, (dsts, rank))| {
            let share = rank / dsts.len() as f64;
            dsts.iter().map(|&d| (d, share)).collect::<Vec<_>>()
        });
        ranks = contribs.reduce_by_key(|a, b| a + b).map_values(|&s| 0.15 + 0.85 * s);
        if checkpoint_every > 0 && it % checkpoint_every == 0 {
            // break the lineage as the paper describes ("checkpoints every
            // ten iterations to break lineages and prevent OOM")
            ranks = ranks.checkpoint();
        }
    }
    ranks.collect()
}

/// Result of the accelerated run.
#[derive(Debug)]
pub struct AcceleratedOutcome {
    /// Global ranks (probability-normalised, as the LPF PageRank computes).
    pub ranks: Vec<f32>,
    /// Iterations until the `eps` tolerance (`n_ε` in Table 4).
    pub iters: u32,
    /// Final residual.
    pub residual: f32,
}

/// Accelerated-Spark PageRank: hook LPF from the sparksim workers.
///
/// `compute` selects the process-local backend (PJRT artifacts or native);
/// `eps`/`max_iters` mirror the paper's `ε = 10⁻⁷` with `n_ε` cut-off.
/// One-shot sugar over [`accelerated_pagerank_runs`].
#[allow(clippy::too_many_arguments)]
pub fn accelerated_pagerank(
    sc: &Spark,
    graph: &Coo,
    compute: Compute,
    alpha: f32,
    eps: f32,
    max_iters: u32,
    nnz_pad: usize,
    master_tag: &str,
) -> crate::core::Result<AcceleratedOutcome> {
    let mut outs = accelerated_pagerank_runs(
        sc,
        graph,
        compute,
        alpha,
        &[(eps, max_iters)],
        nnz_pad,
        master_tag,
    )?;
    Ok(outs.pop().expect("one run requested"))
}

/// The repeated-job form of the §4.3 integration: every worker performs the
/// rendezvous **once** (`Init::over_master`) and then issues one `hook` per
/// entry of `runs` — the paper's "may call `lpf_hook` any number of times".
/// Hook epochs on one master ride a warm team (fabric, arenas, and tuned
/// barrier are reset, not rebuilt, between runs — see `docs/pool.md`), so
/// per-query cost excludes context construction, exactly the hot-team
/// executor's contract for `exec` jobs.
pub fn accelerated_pagerank_runs(
    sc: &Spark,
    graph: &Coo,
    compute: Compute,
    alpha: f32,
    runs: &[(f32, u32)],
    nnz_pad: usize,
    master_tag: &str,
) -> crate::core::Result<Vec<AcceleratedOutcome>> {
    let cluster = sc.cluster();
    let p = cluster.num_workers() as u32;
    // §4.3 step 1–2: collect worker hostnames, dedupe, broadcast. Each
    // worker then derives (p, s, master) from the broadcast array.
    let mut hostnames = cluster.hostnames().to_vec();
    hostnames.sort();
    hostnames.dedup();
    let broadcast: Arc<Vec<String>> = Arc::new(hostnames);
    let master = format!("{}:{}", broadcast[0], master_tag);
    // worker-side data: each worker holds its row block (direct access —
    // the advantage over Alchemist's disjoint server the paper highlights)
    let blocks = Arc::new(partition(graph, p, nnz_pad)?);
    let compute = Arc::new(compute);
    let runs: Arc<Vec<(f32, u32)>> = Arc::new(runs.to_vec());
    let n_runs = runs.len();
    let outs: Vec<crate::core::Result<Vec<PrOutcome>>> =
        cluster.run_on_each_worker(move |wid| {
            // derive (p, s): position of my hostname in the broadcast array
            // — here 1:1 worker:process, as in the paper's Ivy-10 runs
            let s = wid as u32;
            let nprocs = broadcast.len() as u32;
            let init = Init::over_master(
                &master,
                s,
                nprocs,
                Duration::from_secs(120),
                Platform::shared(),
            )?;
            let block = blocks[wid].clone();
            let compute = (*compute).clone();
            let mut per_run = Vec::with_capacity(runs.len());
            for &(eps, max_iters) in runs.iter() {
                let block = block.clone();
                let compute = compute.clone();
                let out = hook(
                    &init,
                    move |ctx, _| -> crate::core::Result<PrOutcome> {
                        ctx.resize_memory_register(8)?;
                        ctx.resize_message_queue(8 * ctx.p() as usize)?;
                        ctx.sync(SYNC_DEFAULT)?;
                        let mut pr =
                            DistPageRank::new(ctx, block.clone(), compute.clone(), alpha)?;
                        ctx.sync(SYNC_DEFAULT)?;
                        pr.run(ctx, eps, max_iters)
                    },
                    Args::none(),
                )?;
                per_run.push(out?);
            }
            init.finalize();
            Ok(per_run)
        });
    let mut per_worker: Vec<Vec<PrOutcome>> = Vec::with_capacity(outs.len());
    for o in outs {
        per_worker.push(o?);
    }
    let mut results = Vec::with_capacity(n_runs);
    for j in 0..n_runs {
        let mut ranks = Vec::with_capacity(graph.n);
        let mut iters = 0;
        let mut residual = 0f32;
        for w in &per_worker {
            let o = &w[j];
            ranks.extend_from_slice(&o.ranks);
            iters = o.iters;
            residual = o.residual;
        }
        ranks.truncate(graph.n);
        results.push(AcceleratedOutcome { ranks, iters, residual });
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphblas::pagerank_serial;
    use crate::graphgen::{cage_like, rmat, RmatConfig};

    #[test]
    fn pure_spark_matches_canonical_formulation() {
        // tiny graph, hand-checkable: 0→1, 1→0, 1→2, 2→0 (no dangling)
        let edges = vec![(0u32, 1u32), (1, 0), (1, 2), (2, 0)];
        let sc = Spark::new(2, 4);
        let out = pure_spark_pagerank(&sc, &edges, 10, 10);
        let mut got = out.clone();
        got.sort_by_key(|&(k, _)| k);
        // serial canonical iteration
        let mut r = [1.0f64; 3];
        let adj = [vec![1], vec![0, 2], vec![0]];
        for _ in 0..10 {
            let mut c = [0f64; 3];
            for (u, dsts) in adj.iter().enumerate() {
                for &d in dsts {
                    c[d as usize] += r[u] / dsts.len() as f64;
                }
            }
            for v in 0..3 {
                r[v] = 0.15 + 0.85 * c[v];
            }
        }
        for (v, (k, rank)) in got.iter().enumerate() {
            assert_eq!(*k as usize, v);
            assert!((rank - r[v]).abs() < 1e-9, "v{v}: {rank} vs {}", r[v]);
        }
    }

    #[test]
    fn accelerated_matches_serial_oracle() {
        let g = cage_like(96, 3, 17);
        let sc = Spark::new(4, 8);
        let nnz_pad = (g.edges.len() / 4 + g.n).next_power_of_two();
        let out = accelerated_pagerank(
            &sc,
            &g,
            Compute::Native,
            0.85,
            1e-6,
            100,
            nnz_pad,
            "t-acc-1",
        )
        .unwrap();
        let (want, _) = pagerank_serial(&g, 0.85, 1e-6, 100);
        assert_eq!(out.ranks.len(), want.len());
        for v in 0..g.n {
            assert!(
                (out.ranks[v] - want[v]).abs() < 1e-5,
                "rank[{v}]: {} vs {}",
                out.ranks[v],
                want[v]
            );
        }
        assert!(out.iters > 1 && out.residual <= 1e-6);
    }

    #[test]
    fn repeated_runs_on_one_init_match_separate_invocations() {
        // the Table-4 shape: several PageRank queries against the same
        // resident workers — one rendezvous, one warm team, N hooks
        let g = cage_like(64, 3, 5);
        let sc = Spark::new(2, 4);
        let nnz_pad = (g.edges.len() / 2 + g.n).next_power_of_two();
        let runs = [(0f32, 1u32), (1e-6, 50), (0f32, 3)];
        let multi = accelerated_pagerank_runs(
            &sc,
            &g,
            Compute::Native,
            0.85,
            &runs,
            nnz_pad,
            "t-acc-multi",
        )
        .unwrap();
        assert_eq!(multi.len(), runs.len());
        for (j, &(eps, max_iters)) in runs.iter().enumerate() {
            let single = accelerated_pagerank(
                &sc,
                &g,
                Compute::Native,
                0.85,
                eps,
                max_iters,
                nnz_pad,
                &format!("t-acc-single-{j}"),
            )
            .unwrap();
            assert_eq!(multi[j].iters, single.iters, "run {j}");
            assert_eq!(multi[j].ranks, single.ranks, "run {j}: warm runs bit-identical");
        }
    }

    #[test]
    fn accelerated_handles_dangling_where_pure_spark_does_not() {
        let g = rmat(&RmatConfig::new(7, 6, 23));
        assert!(g.dangling_count() > 0);
        let sc = Spark::new(2, 4);
        let nnz_pad = (g.edges.len() / 2 + g.n).next_power_of_two();
        let out = accelerated_pagerank(
            &sc,
            &g,
            Compute::Native,
            0.85,
            1e-6,
            80,
            nnz_pad,
            "t-acc-2",
        )
        .unwrap();
        // probability normalisation only holds with dangling handling
        let sum: f32 = out.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "Σranks = {sum}");
    }
}
