//! RDDs: lazy, partitioned, lineage-bearing datasets.
//!
//! Narrow ops (map/flatMap/filter/mapValues) recompute through the lineage
//! inside each partition task; wide ops (reduceByKey, join) cut stages and
//! materialise a hash shuffle, driven stage-by-stage from the action — the
//! same execution model as Spark's DAG scheduler, minus the cluster.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Cluster;

/// Engine statistics (read by the Table-4 harness).
#[derive(Debug, Default)]
pub struct SparkStats {
    /// Shuffles materialised.
    pub shuffles: AtomicU64,
    /// Records that crossed a shuffle boundary.
    pub shuffle_records: AtomicU64,
    /// Partition tasks executed.
    pub tasks: AtomicU64,
    /// Checkpoints taken.
    pub checkpoints: AtomicU64,
    /// Superstep-lowered fused stages executed ([`super::fused`]): whole
    /// map → shuffle → reduceByKey pipelines that ran as one pool job
    /// instead of materialised stages.
    pub fused_stages: AtomicU64,
    /// Records that crossed the fused path's one coalesced total-exchange
    /// (post map-side combine — compare against `shuffle_records`).
    pub fused_exchange_records: AtomicU64,
}

/// The driver handle.
#[derive(Clone)]
pub struct Spark {
    cluster: Arc<Cluster>,
    /// Default partitions for parallelize/shuffles.
    pub default_parallelism: usize,
    stats: Arc<SparkStats>,
}

impl Spark {
    /// New driver over `workers` threads with `parts` default partitions.
    pub fn new(workers: usize, parts: usize) -> Spark {
        Spark {
            cluster: Cluster::new(workers),
            default_parallelism: parts.max(1),
            stats: Arc::new(SparkStats::default()),
        }
    }

    /// The executor pool (interop uses this to hook LPF from workers).
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Engine counters.
    pub fn stats(&self) -> &SparkStats {
        &self.stats
    }

    /// Create an RDD from a driver-side collection.
    pub fn parallelize<T: Clone + Send + Sync + 'static>(
        &self,
        data: Vec<T>,
        parts: usize,
    ) -> Rdd<T> {
        let parts = parts.max(1);
        // one-pass slicing: the old `skip(i·chunk).take(chunk)` per
        // partition walked the prefix again for every partition — O(n·parts)
        let chunk = data.len().div_ceil(parts).max(1);
        let mut partitions: Vec<Vec<T>> = data.chunks(chunk).map(|c| c.to_vec()).collect();
        partitions.resize_with(parts, Vec::new);
        Rdd {
            spark: self.clone(),
            node: Arc::new(Materialized { parts: Arc::new(partitions) }),
        }
    }
}

/// Stage preparation: materialise every wide dependency below a node.
/// Driven from actions (driver side), never from inside a worker task —
/// which is what makes the fixed pool deadlock-free.
pub(crate) trait Stage: Send + Sync {
    fn prepare(&self, spark: &Spark);
}

pub(crate) trait RddNode<T: Send>: Stage {
    fn parts(&self) -> usize;
    /// Compute one partition (narrow lineage only; `prepare` has run).
    fn compute(&self, part: usize) -> Vec<T>;
}

/// A lazy, partitioned dataset.
pub struct Rdd<T: Send + 'static> {
    spark: Spark,
    node: Arc<dyn RddNode<T>>,
}

impl<T: Send + 'static> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Rdd { spark: self.spark.clone(), node: self.node.clone() }
    }
}

impl<T: Send + 'static> Rdd<T> {
    /// Lineage root, for the fused superstep lowering ([`super::fused`]).
    pub(crate) fn node(&self) -> &Arc<dyn RddNode<T>> {
        &self.node
    }

    /// Owning driver handle.
    pub(crate) fn spark(&self) -> &Spark {
        &self.spark
    }
}

pub(crate) fn fx_hash<K: Hash>(k: &K) -> u64 {
    // FxHash-style multiply hash via std DefaultHasher is fine here.
    let mut h = std::collections::hash_map::DefaultHasher::new();
    k.hash(&mut h);
    h.finish()
}

impl<T: Clone + Send + Sync + 'static> Rdd<T> {
    /// Number of partitions.
    pub fn num_partitions(&self) -> usize {
        self.node.parts()
    }

    /// Narrow: elementwise map.
    pub fn map<U: Clone + Send + Sync + 'static>(
        &self,
        f: impl Fn(&T) -> U + Send + Sync + 'static,
    ) -> Rdd<U> {
        let parent = self.node.clone();
        Rdd {
            spark: self.spark.clone(),
            node: Arc::new(Narrow {
                parent,
                f: Arc::new(move |v: Vec<T>| v.iter().map(&f).collect()),
            }),
        }
    }

    /// Narrow: flat map.
    pub fn flat_map<U: Clone + Send + Sync + 'static>(
        &self,
        f: impl Fn(&T) -> Vec<U> + Send + Sync + 'static,
    ) -> Rdd<U> {
        let parent = self.node.clone();
        Rdd {
            spark: self.spark.clone(),
            node: Arc::new(Narrow {
                parent,
                f: Arc::new(move |v: Vec<T>| v.iter().flat_map(&f).collect()),
            }),
        }
    }

    /// Narrow: filter.
    pub fn filter(&self, f: impl Fn(&T) -> bool + Send + Sync + 'static) -> Rdd<T> {
        let parent = self.node.clone();
        Rdd {
            spark: self.spark.clone(),
            node: Arc::new(Narrow {
                parent,
                f: Arc::new(move |v: Vec<T>| v.into_iter().filter(|x| f(x)).collect()),
            }),
        }
    }

    /// Action: gather every partition to the driver.
    pub fn collect(&self) -> Vec<T> {
        self.node.prepare(&self.spark);
        let node = self.node.clone();
        let stats = self.spark.stats.clone();
        let tasks: Vec<Box<dyn FnOnce() -> Vec<T> + Send>> = (0..self.node.parts())
            .map(|p| {
                let node = node.clone();
                let stats = stats.clone();
                Box::new(move || {
                    stats.tasks.fetch_add(1, Ordering::Relaxed);
                    node.compute(p)
                }) as _
            })
            .collect();
        self.spark.cluster.run_tasks(tasks).into_iter().flatten().collect()
    }

    /// Action: count elements.
    pub fn count(&self) -> usize {
        self.node.prepare(&self.spark);
        let node = self.node.clone();
        let stats = self.spark.stats.clone();
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..self.node.parts())
            .map(|p| {
                let node = node.clone();
                let stats = stats.clone();
                Box::new(move || {
                    stats.tasks.fetch_add(1, Ordering::Relaxed);
                    node.compute(p).len()
                }) as _
            })
            .collect();
        self.spark.cluster.run_tasks(tasks).into_iter().sum()
    }

    /// Checkpoint: force materialisation and cut the lineage (Spark writes
    /// to reliable storage; we hold the partitions in the driver — the
    /// lineage-truncation cost structure is identical).
    pub fn checkpoint(&self) -> Rdd<T> {
        self.node.prepare(&self.spark);
        let node = self.node.clone();
        let stats = self.spark.stats.clone();
        let tasks: Vec<Box<dyn FnOnce() -> Vec<T> + Send>> = (0..self.node.parts())
            .map(|p| {
                let node = node.clone();
                Box::new(move || node.compute(p)) as _
            })
            .collect();
        let parts = self.spark.cluster.run_tasks(tasks);
        stats.checkpoints.fetch_add(1, Ordering::Relaxed);
        Rdd {
            spark: self.spark.clone(),
            node: Arc::new(Materialized { parts: Arc::new(parts) }),
        }
    }
}

impl<K, V> Rdd<(K, V)>
where
    K: Clone + Eq + Hash + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    /// Narrow: map over values.
    pub fn map_values<W: Clone + Send + Sync + 'static>(
        &self,
        f: impl Fn(&V) -> W + Send + Sync + 'static,
    ) -> Rdd<(K, W)> {
        self.map(move |(k, v)| (k.clone(), f(v)))
    }

    /// Wide: shuffle by key and combine values with `op`.
    pub fn reduce_by_key(&self, op: impl Fn(V, V) -> V + Send + Sync + 'static) -> Rdd<(K, V)> {
        let parts = self.spark.default_parallelism;
        Rdd {
            spark: self.spark.clone(),
            node: Arc::new(ShuffleReduce {
                parent: self.node.clone(),
                parts,
                op: Arc::new(op),
                out: Mutex::new(None),
            }),
        }
    }

    /// Wide: inner hash join.
    pub fn join<W: Clone + Send + Sync + 'static>(&self, other: &Rdd<(K, W)>) -> Rdd<(K, (V, W))> {
        let parts = self.spark.default_parallelism;
        Rdd {
            spark: self.spark.clone(),
            node: Arc::new(ShuffleJoin {
                left: self.node.clone(),
                right: other.node.clone(),
                parts,
                out: Mutex::new(None),
            }),
        }
    }
}

// ------------------------------------------------------------------ nodes

struct Materialized<T> {
    parts: Arc<Vec<Vec<T>>>,
}

impl<T: Clone + Send + Sync> Stage for Materialized<T> {
    fn prepare(&self, _spark: &Spark) {}
}

impl<T: Clone + Send + Sync> RddNode<T> for Materialized<T> {
    fn parts(&self) -> usize {
        self.parts.len()
    }
    fn compute(&self, part: usize) -> Vec<T> {
        self.parts[part].clone()
    }
}

type PartFn<T, U> = Arc<dyn Fn(Vec<T>) -> Vec<U> + Send + Sync>;

struct Narrow<T: Send, U> {
    parent: Arc<dyn RddNode<T>>,
    f: PartFn<T, U>,
}

impl<T: Send + 'static, U: Send> Stage for Narrow<T, U> {
    fn prepare(&self, spark: &Spark) {
        self.parent.prepare(spark);
    }
}

impl<T: Send + 'static, U: Send> RddNode<U> for Narrow<T, U> {
    fn parts(&self) -> usize {
        self.parent.parts()
    }
    fn compute(&self, part: usize) -> Vec<U> {
        (self.f)(self.parent.compute(part))
    }
}

/// Hash-partition records into `parts` buckets (the shuffle write side).
fn hash_partition<K: Hash, V>(records: Vec<(K, V)>, parts: usize) -> Vec<Vec<(K, V)>> {
    let mut buckets: Vec<Vec<(K, V)>> = (0..parts).map(|_| Vec::new()).collect();
    for (k, v) in records {
        let b = (fx_hash(&k) as usize) % parts;
        buckets[b].push((k, v));
    }
    buckets
}

struct ShuffleReduce<K: Send, V: Send> {
    parent: Arc<dyn RddNode<(K, V)>>,
    parts: usize,
    op: Arc<dyn Fn(V, V) -> V + Send + Sync>,
    out: Mutex<Option<Arc<Vec<Vec<(K, V)>>>>>,
}

impl<K, V> Stage for ShuffleReduce<K, V>
where
    K: Clone + Eq + Hash + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    fn prepare(&self, spark: &Spark) {
        if self.out.lock().unwrap().is_some() {
            return;
        }
        self.parent.prepare(spark);
        // map side: compute parent partitions (on workers) + hash-bucket
        let parent = self.parent.clone();
        let parts = self.parts;
        let tasks: Vec<Box<dyn FnOnce() -> Vec<Vec<(K, V)>> + Send>> = (0..parent.parts())
            .map(|p| {
                let parent = parent.clone();
                Box::new(move || hash_partition(parent.compute(p), parts)) as _
            })
            .collect();
        let mapped = spark.cluster.run_tasks(tasks);
        let records: u64 = mapped.iter().flatten().map(|b| b.len() as u64).sum();
        spark.stats.shuffles.fetch_add(1, Ordering::Relaxed);
        spark.stats.shuffle_records.fetch_add(records, Ordering::Relaxed);
        // reduce side: merge bucket b of every map output
        let mapped = Arc::new(mapped);
        let op = self.op.clone();
        let tasks: Vec<Box<dyn FnOnce() -> Vec<(K, V)> + Send>> = (0..parts)
            .map(|b| {
                let mapped = mapped.clone();
                let op = op.clone();
                Box::new(move || {
                    let mut agg: HashMap<K, V> = HashMap::new();
                    for m in mapped.iter() {
                        for (k, v) in &m[b] {
                            match agg.remove(k) {
                                Some(old) => {
                                    agg.insert(k.clone(), op(old, v.clone()));
                                }
                                None => {
                                    agg.insert(k.clone(), v.clone());
                                }
                            }
                        }
                    }
                    agg.into_iter().collect()
                }) as _
            })
            .collect();
        let reduced = spark.cluster.run_tasks(tasks);
        *self.out.lock().unwrap() = Some(Arc::new(reduced));
    }
}

impl<K, V> RddNode<(K, V)> for ShuffleReduce<K, V>
where
    K: Clone + Eq + Hash + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    fn parts(&self) -> usize {
        self.parts
    }
    fn compute(&self, part: usize) -> Vec<(K, V)> {
        self.out.lock().unwrap().as_ref().expect("prepare ran")[part].clone()
    }
}

struct ShuffleJoin<K: Send, V: Send, W: Send> {
    left: Arc<dyn RddNode<(K, V)>>,
    right: Arc<dyn RddNode<(K, W)>>,
    parts: usize,
    out: Mutex<Option<Arc<Vec<Vec<(K, (V, W))>>>>>,
}

impl<K, V, W> Stage for ShuffleJoin<K, V, W>
where
    K: Clone + Eq + Hash + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
    W: Clone + Send + Sync + 'static,
{
    fn prepare(&self, spark: &Spark) {
        if self.out.lock().unwrap().is_some() {
            return;
        }
        self.left.prepare(spark);
        self.right.prepare(spark);
        let parts = self.parts;
        // left map side
        let left = self.left.clone();
        let tasks: Vec<Box<dyn FnOnce() -> Vec<Vec<(K, V)>> + Send>> = (0..left.parts())
            .map(|p| {
                let left = left.clone();
                Box::new(move || hash_partition(left.compute(p), parts)) as _
            })
            .collect();
        let lmap = Arc::new(spark.cluster.run_tasks(tasks));
        // right map side
        let right = self.right.clone();
        let tasks: Vec<Box<dyn FnOnce() -> Vec<Vec<(K, W)>> + Send>> = (0..right.parts())
            .map(|p| {
                let right = right.clone();
                Box::new(move || hash_partition(right.compute(p), parts)) as _
            })
            .collect();
        let rmap = Arc::new(spark.cluster.run_tasks(tasks));
        let records: u64 = lmap.iter().flatten().map(|b| b.len() as u64).sum::<u64>()
            + rmap.iter().flatten().map(|b| b.len() as u64).sum::<u64>();
        spark.stats.shuffles.fetch_add(2, Ordering::Relaxed);
        spark.stats.shuffle_records.fetch_add(records, Ordering::Relaxed);
        // reduce side: hash join per bucket
        let tasks: Vec<Box<dyn FnOnce() -> Vec<(K, (V, W))> + Send>> = (0..parts)
            .map(|b| {
                let lmap = lmap.clone();
                let rmap = rmap.clone();
                Box::new(move || {
                    let mut ltab: HashMap<K, Vec<V>> = HashMap::new();
                    for m in lmap.iter() {
                        for (k, v) in &m[b] {
                            ltab.entry(k.clone()).or_default().push(v.clone());
                        }
                    }
                    let mut out = Vec::new();
                    for m in rmap.iter() {
                        for (k, w) in &m[b] {
                            if let Some(vs) = ltab.get(k) {
                                for v in vs {
                                    out.push((k.clone(), (v.clone(), w.clone())));
                                }
                            }
                        }
                    }
                    out
                }) as _
            })
            .collect();
        let joined = spark.cluster.run_tasks(tasks);
        *self.out.lock().unwrap() = Some(Arc::new(joined));
    }
}

impl<K, V, W> RddNode<(K, (V, W))> for ShuffleJoin<K, V, W>
where
    K: Clone + Eq + Hash + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
    W: Clone + Send + Sync + 'static,
{
    fn parts(&self) -> usize {
        self.parts
    }
    fn compute(&self, part: usize) -> Vec<(K, (V, W))> {
        self.out.lock().unwrap().as_ref().expect("prepare ran")[part].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_filter_collect() {
        let sc = Spark::new(2, 4);
        let r = sc.parallelize((0..100u32).collect(), 4);
        let out = r.map(|x| x * 2).filter(|x| x % 3 == 0).collect();
        let mut want: Vec<u32> = (0..100).map(|x| x * 2).filter(|x| x % 3 == 0).collect();
        let mut got = out.clone();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn parallelize_slices_evenly_including_edge_cases() {
        let sc = Spark::new(2, 4);
        // round-trip order preserved partition by partition
        let r = sc.parallelize((0..10u32).collect(), 4);
        assert_eq!(r.num_partitions(), 4);
        assert_eq!(r.collect(), (0..10u32).collect::<Vec<_>>());
        // empty data still yields `parts` (empty) partitions
        let e = sc.parallelize(Vec::<u32>::new(), 3);
        assert_eq!(e.num_partitions(), 3);
        assert_eq!(e.count(), 0);
        // fewer elements than partitions
        let s = sc.parallelize(vec![7u32], 5);
        assert_eq!(s.num_partitions(), 5);
        assert_eq!(s.collect(), vec![7]);
    }

    #[test]
    fn flat_map_and_count() {
        let sc = Spark::new(2, 3);
        let r = sc.parallelize(vec![1u32, 2, 3], 2);
        assert_eq!(r.flat_map(|&x| vec![x; x as usize]).count(), 6);
    }

    #[test]
    fn reduce_by_key_sums() {
        let sc = Spark::new(3, 5);
        let pairs: Vec<(u32, u64)> = (0..1000).map(|i| (i % 7, 1u64)).collect();
        let r = sc.parallelize(pairs, 8).reduce_by_key(|a, b| a + b);
        let mut out = r.collect();
        out.sort_unstable();
        let want: Vec<(u32, u64)> =
            (0..7).map(|k| (k, (1000 + 6 - k as u64) / 7)).collect();
        // counts: keys 0..6 appear ceil/floor of 1000/7
        let total: u64 = out.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 1000);
        assert_eq!(out.len(), 7);
        let _ = want;
        assert!(sc.stats().shuffles.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn join_matches_pairs() {
        let sc = Spark::new(2, 4);
        let a = sc.parallelize(vec![(1u32, "a"), (2, "b"), (3, "c")], 2);
        let b = sc.parallelize(vec![(2u32, 20), (3, 30), (4, 40)], 2);
        let mut out = a.join(&b).collect();
        out.sort_by_key(|&(k, _)| k);
        assert_eq!(out, vec![(2, ("b", 20)), (3, ("c", 30))]);
    }

    #[test]
    fn checkpoint_cuts_lineage_same_data() {
        let sc = Spark::new(2, 4);
        let base = sc.parallelize((0..50u32).collect(), 4);
        let chained = base.map(|x| x + 1).map(|x| x * 2);
        let cp = chained.checkpoint();
        let mut a = chained.collect();
        let mut b = cp.collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        assert_eq!(sc.stats().checkpoints.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn lineage_recomputes_deterministically() {
        let sc = Spark::new(2, 3);
        let r = sc.parallelize((0..30u32).collect(), 3).map(|x| x * x);
        let mut a = r.collect();
        let mut b = r.collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }
}
