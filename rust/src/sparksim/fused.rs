//! Superstep lowering for RDD pipelines: run a fused narrow chain plus one
//! wide op (map → shuffle → reduceByKey) as a **single superstep plan** on
//! a warm LPF [`Pool`], instead of materialising a hash shuffle per stage.
//!
//! The staged engine ([`super::rdd`]) clones every record through map-side
//! bucket vectors, a driver-held shuffle table, and reduce-side tasks. The
//! lowered plan follows the group-communication-patterns observation
//! (shuffle-shaped exchanges belong on structured collectives): each pool
//! process computes its partitions through the narrow lineage, **combines
//! map-side** (the optimisation the staged path lacks), and routes the
//! combined records in **one coalesced total-exchange** — the same
//! sizes-alltoall + put-at-prefix-offset plan as the immortal sample sort —
//! before a final local merge. One superstep of payload traffic per
//! pipeline, `SparkStats::fused_*` counters make the collapse observable.
//!
//! Keys/values travel as parallel `u64`/`f64` Pod arrays (tuples are not
//! Pod). Values merge with a caller-supplied associative op; merge order
//! within a key is unspecified (both engines share this property — use
//! exactly-representable values when asserting equality).

use std::collections::HashMap;
use std::sync::atomic::Ordering;

use crate::collectives::Coll;
use crate::core::{Args, Result, SYNC_DEFAULT};
use crate::pool::Pool;

use super::rdd::{fx_hash, Rdd};

/// Lower `rdd.map(map).reduce_by_key(reduce).collect()` onto one pool-run
/// superstep plan. Wide dependencies *upstream* of `rdd` are prepared
/// through the staged engine first (the lowering fuses the final narrow
/// chain + one wide op); the fused stage itself touches no shuffle
/// machinery. Returns the reduced pairs (unordered).
pub fn fused_map_reduce<T, M, R>(
    rdd: &Rdd<T>,
    pool: &Pool,
    map: M,
    reduce: R,
) -> Result<Vec<(u64, f64)>>
where
    T: Clone + Send + Sync + 'static,
    M: Fn(&T) -> (u64, f64) + Sync,
    R: Fn(f64, f64) -> f64 + Sync,
{
    // upstream wide deps still run staged — the fusion boundary is the
    // last narrow chain + the closing reduceByKey
    rdd.node().prepare(rdd.spark());
    let node = rdd.node().clone();
    let nparts = node.parts();
    let per_pid = pool.exec(
        |ctx, _| -> Result<(Vec<(u64, f64)>, u64)> {
            let p = ctx.p() as usize;
            let me = ctx.pid() as usize;
            ctx.bootstrap(8, 4 * p + 8)?;
            // narrow chain, fused by lineage composition + map-side combine
            let mut agg: HashMap<u64, f64> = HashMap::new();
            let mut part = me;
            while part < nparts {
                for rec in node.compute(part) {
                    let (k, v) = map(&rec);
                    match agg.remove(&k) {
                        Some(old) => agg.insert(k, reduce(old, v)),
                        None => agg.insert(k, v),
                    };
                }
                part += p;
            }
            // route combined records by key hash (same placement rule as
            // the staged shuffle)
            let mut buckets: Vec<Vec<(u64, f64)>> = vec![Vec::new(); p];
            for (k, v) in agg {
                buckets[(fx_hash(&k) as usize) % p].push((k, v));
            }
            let sizes: Vec<u64> = buckets.iter().map(|b| b.len() as u64).collect();
            let sent: u64 = sizes.iter().sum::<u64>() - sizes[me];
            let coll = Coll::new(ctx, 8 * p)?;
            ctx.sync(SYNC_DEFAULT)?;
            let mut size_matrix = vec![0u64; p * p]; // [sender][receiver]
            coll.allgather(ctx, &sizes, &mut size_matrix)?;
            let total_in: usize =
                (0..p).map(|s| size_matrix[s * p + me] as usize).sum();
            let total_out: usize = buckets.iter().map(|b| b.len()).sum();
            // one coalesced total-exchange: keys + values side by side
            let send_k = ctx.alloc_local::<u64>(total_out.max(1))?;
            let send_v = ctx.alloc_local::<f64>(total_out.max(1))?;
            let recv_k = ctx.alloc_global::<u64>(total_in.max(1))?;
            let recv_v = ctx.alloc_global::<f64>(total_in.max(1))?;
            ctx.sync(SYNC_DEFAULT)?;
            let flat_k: Vec<u64> = buckets.iter().flatten().map(|&(k, _)| k).collect();
            let flat_v: Vec<f64> = buckets.iter().flatten().map(|&(_, v)| v).collect();
            ctx.write(send_k, 0, &flat_k)?;
            ctx.write(send_v, 0, &flat_v)?;
            ctx.superstep(|ep| {
                let mut my_off = 0usize;
                for (dst, b) in buckets.iter().enumerate() {
                    if !b.is_empty() {
                        let dst_off: usize = (0..me)
                            .map(|s| size_matrix[s * p + dst] as usize)
                            .sum();
                        // local bucket routes as a self-put: one uniform plan
                        ep.put_slice(send_k, my_off, dst as u32, recv_k, dst_off, b.len())?;
                        ep.put_slice(send_v, my_off, dst as u32, recv_v, dst_off, b.len())?;
                        my_off += b.len();
                    }
                }
                Ok(())
            })?;
            let mut keys = vec![0u64; total_in];
            let mut vals = vec![0f64; total_in];
            ctx.read(recv_k, 0, &mut keys)?;
            ctx.read(recv_v, 0, &mut vals)?;
            let mut merged: HashMap<u64, f64> = HashMap::with_capacity(total_in);
            for (k, v) in keys.into_iter().zip(vals) {
                match merged.remove(&k) {
                    Some(old) => merged.insert(k, reduce(old, v)),
                    None => merged.insert(k, v),
                };
            }
            ctx.dealloc(send_k)?;
            ctx.dealloc(send_v)?;
            ctx.dealloc(recv_k)?;
            ctx.dealloc(recv_v)?;
            coll.free(ctx)?;
            ctx.sync(SYNC_DEFAULT)?;
            Ok((merged.into_iter().collect(), sent))
        },
        Args::none(),
    )?;
    let stats = rdd.spark().stats();
    stats.fused_stages.fetch_add(1, Ordering::Relaxed);
    let mut out = Vec::new();
    for r in per_pid {
        let (pairs, sent) = r?;
        stats.fused_exchange_records.fetch_add(sent, Ordering::Relaxed);
        out.extend(pairs);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ctx::Platform;
    use crate::sparksim::Spark;
    use crate::util::rng::XorShift64;

    #[test]
    fn fused_matches_staged_reduce_by_key() {
        let sc = Spark::new(4, 8);
        let pool = Pool::new(Platform::shared().checked(true), 4);
        let mut rng = XorShift64::new(42);
        let data: Vec<u64> = (0..20_000).map(|_| rng.below(512)).collect();
        let rdd = sc.parallelize(data, 16).map(|&x| x);
        // staged: materialised hash shuffle
        let mut staged = rdd
            .map(|&x| (x % 97, (x / 7) as f64))
            .reduce_by_key(|a, b| a + b)
            .collect();
        staged.sort_by_key(|&(k, _)| k);
        let shuffles_after_staged = sc.stats().shuffles.load(Ordering::Relaxed);
        // fused: one superstep plan (values are integral f64 → + is exact
        // in any merge order)
        let mut fused =
            fused_map_reduce(&rdd, &pool, |&x| (x % 97, (x / 7) as f64), |a, b| a + b).unwrap();
        fused.sort_by_key(|&(k, _)| k);
        assert_eq!(staged, fused);
        // the fused path never touched the shuffle machinery
        assert_eq!(sc.stats().shuffles.load(Ordering::Relaxed), shuffles_after_staged);
        assert_eq!(sc.stats().fused_stages.load(Ordering::Relaxed), 1);
        assert!(sc.stats().fused_exchange_records.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn fused_handles_empty_and_tiny_inputs() {
        let sc = Spark::new(2, 4);
        let pool = Pool::new(Platform::shared().checked(true), 2);
        let empty = sc.parallelize(Vec::<u64>::new(), 4);
        let out = fused_map_reduce(&empty, &pool, |&x| (x, 1.0), |a, b| a + b).unwrap();
        assert!(out.is_empty());
        let tiny = sc.parallelize(vec![5u64], 4);
        let out = fused_map_reduce(&tiny, &pool, |&x| (x, 2.0), |a, b| a + b).unwrap();
        assert_eq!(out, vec![(5, 2.0)]);
    }

    #[test]
    fn fused_runs_after_upstream_wide_dep() {
        // upstream reduceByKey runs staged; the fused stage consumes it
        let sc = Spark::new(3, 6);
        let pool = Pool::new(Platform::shared().checked(true), 3);
        let pairs: Vec<(u64, u64)> = (0..3000).map(|i| (i % 50, 1u64)).collect();
        let upstream = sc.parallelize(pairs, 6).reduce_by_key(|a, b| a + b);
        let got = fused_map_reduce(
            &upstream,
            &pool,
            |&(k, c)| (k % 5, c as f64),
            |a, b| a + b,
        )
        .unwrap();
        let total: f64 = got.iter().map(|&(_, v)| v).sum();
        assert_eq!(total, 3000.0);
        assert_eq!(got.len(), 5);
    }
}
