//! sparksim: a miniature Spark-like RDD dataflow engine.
//!
//! The paper's Table 4 calls an LPF PageRank *from Spark* and compares it
//! against a pure-Spark PageRank. Spark itself (plus JVM, HDFS, JNI) is
//! not available here, so — per the substitution rule — we build the
//! smallest engine that reproduces the costs that experiment measures:
//!
//! * **lazy RDD DAG** with narrow (map/flatMap/filter/mapValues) and wide
//!   (reduceByKey, join) dependencies;
//! * **hash-shuffle materialisation** at every wide dependency (the real
//!   clone-hash-bucket work, like Spark's shuffle files);
//! * **lineage recomputation** of narrow chains at every action, with
//!   **checkpointing** to cut lineages (the pure-Spark PageRank checkpoints
//!   every ten iterations, as the paper describes);
//! * a fixed pool of **worker threads** executing partition tasks — the
//!   processes that the interop experiment "repurposes as LPF processes"
//!   via `hook` (paper §4.3 / §5 vs. Alchemist).

pub mod fused;
pub mod pagerank;
pub mod rdd;

pub use fused::fused_map_reduce;
pub use rdd::{Rdd, Spark};

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// A unit of work shipped to a worker.
type Job = Box<dyn FnOnce() + Send>;

/// A fixed pool of worker threads (the "executors").
pub struct Cluster {
    senders: Vec<Sender<Job>>,
    /// Worker "hostnames" — what the interop bootstrap collects and
    /// broadcasts, mirroring the paper's Spark procedure.
    hostnames: Vec<String>,
    rr: Mutex<usize>,
}

impl Cluster {
    /// Spin up `p` workers.
    pub fn new(p: usize) -> Arc<Cluster> {
        assert!(p > 0);
        let mut senders = Vec::with_capacity(p);
        let mut hostnames = Vec::with_capacity(p);
        for w in 0..p {
            let (tx, rx) = channel::<Job>();
            std::thread::Builder::new()
                .name(format!("sparksim-worker-{w}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawn worker");
            senders.push(tx);
            hostnames.push(format!("worker-{w}.sparksim.local"));
        }
        Arc::new(Cluster { senders, hostnames, rr: Mutex::new(0) })
    }

    /// Number of workers.
    pub fn num_workers(&self) -> usize {
        self.senders.len()
    }

    /// The worker hostnames (interop bootstrap step 1).
    pub fn hostnames(&self) -> &[String] {
        &self.hostnames
    }

    /// Run `tasks` across the pool (round-robin), blocking for all results
    /// in order.
    pub fn run_tasks<T: Send + 'static>(
        &self,
        tasks: Vec<Box<dyn FnOnce() -> T + Send>>,
    ) -> Vec<T> {
        let n = tasks.len();
        let (tx, rx) = channel::<(usize, T)>();
        {
            let mut rr = self.rr.lock().unwrap();
            for (i, task) in tasks.into_iter().enumerate() {
                let tx = tx.clone();
                let w = *rr % self.senders.len();
                *rr += 1;
                self.senders[w]
                    .send(Box::new(move || {
                        let out = task();
                        let _ = tx.send((i, out));
                    }))
                    .expect("worker alive");
            }
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rx.recv().expect("task result");
            out[i] = Some(v);
        }
        out.into_iter().map(|o| o.expect("all tasks returned")).collect()
    }

    /// Run exactly one task **pinned to each worker**, blocking for all.
    /// This is the interop entry: each worker becomes one LPF process.
    pub fn run_on_each_worker<T: Send + 'static>(
        &self,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, T)>();
        for (w, sender) in self.senders.iter().enumerate() {
            let tx = tx.clone();
            let f = f.clone();
            sender
                .send(Box::new(move || {
                    let out = f(w);
                    let _ = tx.send((w, out));
                }))
                .expect("worker alive");
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..self.senders.len()).map(|_| None).collect();
        for _ in 0..self.senders.len() {
            let (w, v) = rx.recv().expect("worker result");
            out[w] = Some(v);
        }
        out.into_iter().map(|o| o.expect("all workers returned")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_tasks_returns_in_order() {
        let c = Cluster::new(3);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..10usize).map(|i| Box::new(move || i * i) as _).collect();
        assert_eq!(c.run_tasks(tasks), (0..10usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_on_each_worker_pins_ids() {
        let c = Cluster::new(4);
        let ids = c.run_on_each_worker(|w| w);
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn hostnames_are_unique() {
        let c = Cluster::new(4);
        let mut h = c.hostnames().to_vec();
        h.sort();
        h.dedup();
        assert_eq!(h.len(), 4);
    }
}
