//! Process barriers.
//!
//! The paper's shared-memory `lpf_sync` brackets its phases with two
//! barriers and uses an *auto-tuned hierarchical* barrier ("hierar.",
//! Table 1, citing Nishtala's autotuning work) which is `O(log p)` time and
//! `O(p)` memory, against the naive flat barrier's `O(p)` time.
//!
//! Three implementations, one trait:
//! * [`FlatBarrier`] — centralised counter + condvar. `O(p)` wake chain.
//! * [`DisseminationBarrier`] — ⌈log₂ p⌉ rounds of pairwise flags; this is
//!   the classic hierarchical-class barrier that scales as `O(log p)`.
//! * [`AutoBarrier`] — picks between the two by a quick online calibration,
//!   mirroring the auto-tuning the paper cites.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::core::Pid;

/// Process-wide cache of calibration outcomes: `p → use dissemination?`.
/// One cell per `p`: the map lock is only held for map access, while the
/// measurement runs under the cell's own `OnceLock` — concurrent
/// [`ensure_tuned`] calls for one `p` calibrate exactly once, and
/// [`AutoBarrier::tuned`] (fabric construction) never blocks on a
/// calibration in progress (it falls back to the heuristic until the
/// verdict lands).
fn tuned_cache() -> &'static Mutex<HashMap<u32, Arc<OnceLock<bool>>>> {
    static CACHE: OnceLock<Mutex<HashMap<u32, Arc<OnceLock<bool>>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Calibrate flat vs dissemination for `p` participants once per process
/// (subsequent calls are a cache hit). Runs at pool startup — off the job
/// dispatch path — mirroring the paper's "auto-tuned hierarchical barrier".
pub fn ensure_tuned(p: u32) {
    let cell = {
        let mut cache = tuned_cache().lock().expect("tune cache poisoned");
        cache.entry(p).or_default().clone()
    };
    cell.get_or_init(|| {
        let (_chosen, t_flat, t_diss) = AutoBarrier::calibrate(p, 16);
        t_diss < t_flat
    });
}

/// A reusable barrier for a fixed set of `p` participants.
pub trait Barrier: Send + Sync {
    /// Block until all `p` processes have called `wait` for this episode.
    fn wait(&self, pid: Pid);
    /// Number of participants.
    fn parties(&self) -> u32;
    /// Asymptotic latency class, for `probe`'s ℓ accounting: number of
    /// dependent communication rounds on the critical path.
    fn critical_rounds(&self) -> u32;
    /// Like [`wait`](Barrier::wait), but returns `false` (instead of
    /// blocking forever) once `abort` becomes true. After an aborted wait
    /// the barrier episode is corrupt; the context is fatally dead anyway —
    /// this exists exactly so peers of an aborted process observe
    /// `PeerAborted` at their next collective, as the paper prescribes
    /// (§2.1), rather than deadlock.
    fn wait_abortable(&self, pid: Pid, abort: &AtomicBool) -> bool {
        if abort.load(Ordering::Acquire) {
            return false;
        }
        self.wait(pid);
        true
    }
}

/// Centralised sense-reversing barrier (counter + condvar).
pub struct FlatBarrier {
    p: u32,
    state: Mutex<(u32, u64)>, // (arrived, episode)
    cv: Condvar,
}

impl FlatBarrier {
    /// Barrier for `p` participants.
    pub fn new(p: u32) -> Self {
        assert!(p > 0);
        FlatBarrier { p, state: Mutex::new((0, 0)), cv: Condvar::new() }
    }
}

impl Barrier for FlatBarrier {
    fn wait(&self, _pid: Pid) {
        let mut st = self.state.lock().expect("barrier poisoned");
        let episode = st.1;
        st.0 += 1;
        if st.0 == self.p {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
        } else {
            while st.1 == episode {
                st = self.cv.wait(st).expect("barrier poisoned");
            }
        }
    }
    fn parties(&self) -> u32 {
        self.p
    }
    fn critical_rounds(&self) -> u32 {
        // one gather + one broadcast through a single cell: O(p) chain
        self.p
    }
    fn wait_abortable(&self, _pid: Pid, abort: &AtomicBool) -> bool {
        let mut st = self.state.lock().expect("barrier poisoned");
        let episode = st.1;
        st.0 += 1;
        if st.0 == self.p {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            return true;
        }
        while st.1 == episode {
            if abort.load(Ordering::Acquire) {
                return false;
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(st, Duration::from_millis(5))
                .expect("barrier poisoned");
            st = g;
        }
        true
    }
}

/// Dissemination barrier: ⌈log₂ p⌉ rounds; round `r` signals
/// `(pid + 2^r) mod p` and waits for `(pid − 2^r) mod p`.
///
/// Flags are sense-reversed per episode parity so the structure is reusable
/// without resets. Waiting spins briefly then yields — appropriate both for
/// real multicore and for the single-core CI container this repo runs in.
pub struct DisseminationBarrier {
    p: u32,
    rounds: u32,
    /// flags[parity][round][pid]
    flags: Vec<Vec<Vec<AtomicBool>>>,
    episode: Vec<AtomicU32>, // per-pid episode counter (cache-line padded)
}

/// Pad to avoid false sharing of per-pid episode counters — the exact
/// failure mode the paper warns about for naive shared-memory backends (§3).
const PAD: usize = 8; // 8 × u32 on its own line region

impl DisseminationBarrier {
    /// Barrier for `p` participants.
    pub fn new(p: u32) -> Self {
        assert!(p > 0);
        let rounds = 32 - (p - 1).leading_zeros().min(31);
        let rounds = if p == 1 { 0 } else { rounds };
        let mk_round_flags = || -> Vec<Vec<AtomicBool>> {
            (0..rounds).map(|_| (0..p).map(|_| AtomicBool::new(false)).collect()).collect()
        };
        DisseminationBarrier {
            p,
            rounds,
            flags: vec![mk_round_flags(), mk_round_flags()],
            episode: (0..p as usize * PAD).map(|_| AtomicU32::new(0)).collect(),
        }
    }
}

impl Barrier for DisseminationBarrier {
    fn wait(&self, pid: Pid) {
        if self.p == 1 {
            return;
        }
        let ep = self.episode[pid as usize * PAD].fetch_add(1, Ordering::AcqRel);
        let parity = (ep & 1) as usize;
        let sense = ep & 2 == 0; // flips every reuse of the parity plane
        for r in 0..self.rounds {
            let peer = (pid + (1 << r)) % self.p;
            self.flags[parity][r as usize][peer as usize].store(sense, Ordering::Release);
            let mine = &self.flags[parity][r as usize][pid as usize];
            let mut spins = 0u32;
            while mine.load(Ordering::Acquire) != sense {
                spins += 1;
                if spins > 64 {
                    std::thread::yield_now();
                }
            }
        }
    }
    fn parties(&self) -> u32 {
        self.p
    }
    fn critical_rounds(&self) -> u32 {
        self.rounds
    }
    fn wait_abortable(&self, pid: Pid, abort: &AtomicBool) -> bool {
        if self.p == 1 {
            return !abort.load(Ordering::Acquire);
        }
        let ep = self.episode[pid as usize * PAD].fetch_add(1, Ordering::AcqRel);
        let parity = (ep & 1) as usize;
        let sense = ep & 2 == 0;
        for r in 0..self.rounds {
            let peer = (pid + (1 << r)) % self.p;
            self.flags[parity][r as usize][peer as usize].store(sense, Ordering::Release);
            let mine = &self.flags[parity][r as usize][pid as usize];
            let mut spins = 0u32;
            while mine.load(Ordering::Acquire) != sense {
                spins += 1;
                if spins > 64 {
                    if abort.load(Ordering::Acquire) {
                        return false;
                    }
                    std::thread::yield_now();
                }
            }
        }
        true
    }
}

/// Auto-tuned barrier: calibrates flat vs dissemination at construction and
/// delegates to the winner (paper: "auto-tuned hierarchical barrier").
pub enum AutoBarrier {
    Flat(FlatBarrier),
    Dissemination(DisseminationBarrier),
}

impl AutoBarrier {
    /// Heuristic + optional calibration. Small `p` favours the flat barrier
    /// (fewer atomics); larger `p` the `O(log p)` dissemination structure.
    /// The crossover default (8) matches what calibration finds on this
    /// container; `calibrate` re-measures it.
    pub fn new(p: u32) -> Self {
        if p <= 8 {
            AutoBarrier::Flat(FlatBarrier::new(p))
        } else {
            AutoBarrier::Dissemination(DisseminationBarrier::new(p))
        }
    }

    /// Like [`new`](AutoBarrier::new), but consults the process-wide
    /// calibration cache [`ensure_tuned`] populates at pool startup; falls
    /// back to the size heuristic when no measurement exists for this `p`
    /// (including while one is still running). Fabrics use this
    /// constructor so a pool's one-time tuning carries to the team's
    /// barrier.
    pub fn tuned(p: u32) -> Self {
        let verdict = tuned_cache()
            .lock()
            .expect("tune cache poisoned")
            .get(&p)
            .and_then(|cell| cell.get().copied());
        match verdict {
            Some(true) => AutoBarrier::Dissemination(DisseminationBarrier::new(p)),
            Some(false) => AutoBarrier::Flat(FlatBarrier::new(p)),
            None => AutoBarrier::new(p),
        }
    }

    /// Measure both variants with `iters` episodes of `p` threads and pick
    /// the faster. Used by the ablation bench; `new` uses the cached
    /// heuristic so context creation stays O(p).
    pub fn calibrate(p: u32, iters: u32) -> (Self, f64, f64) {
        fn time_it(b: Arc<dyn Barrier>, p: u32, iters: u32) -> f64 {
            let start = std::time::Instant::now();
            std::thread::scope(|s| {
                for pid in 0..p {
                    let b = b.clone();
                    s.spawn(move || {
                        for _ in 0..iters {
                            b.wait(pid);
                        }
                    });
                }
            });
            start.elapsed().as_secs_f64() / iters as f64
        }
        let t_flat = time_it(Arc::new(FlatBarrier::new(p)), p, iters);
        let t_diss = time_it(Arc::new(DisseminationBarrier::new(p)), p, iters);
        let chosen = if t_flat <= t_diss {
            AutoBarrier::Flat(FlatBarrier::new(p))
        } else {
            AutoBarrier::Dissemination(DisseminationBarrier::new(p))
        };
        (chosen, t_flat, t_diss)
    }
}

impl Barrier for AutoBarrier {
    fn wait(&self, pid: Pid) {
        match self {
            AutoBarrier::Flat(b) => b.wait(pid),
            AutoBarrier::Dissemination(b) => b.wait(pid),
        }
    }
    fn parties(&self) -> u32 {
        match self {
            AutoBarrier::Flat(b) => b.parties(),
            AutoBarrier::Dissemination(b) => b.parties(),
        }
    }
    fn critical_rounds(&self) -> u32 {
        match self {
            AutoBarrier::Flat(b) => b.critical_rounds(),
            AutoBarrier::Dissemination(b) => b.critical_rounds(),
        }
    }
    fn wait_abortable(&self, pid: Pid, abort: &AtomicBool) -> bool {
        match self {
            AutoBarrier::Flat(b) => b.wait_abortable(pid, abort),
            AutoBarrier::Dissemination(b) => b.wait_abortable(pid, abort),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    /// Generic stress: no process may enter episode e+1 before all entered e.
    fn stress(b: Arc<dyn Barrier>, p: u32, episodes: usize) {
        let counter = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for pid in 0..p {
                let b = b.clone();
                let counter = counter.clone();
                s.spawn(move || {
                    for e in 0..episodes {
                        counter.fetch_add(1, Ordering::SeqCst);
                        b.wait(pid);
                        let seen = counter.load(Ordering::SeqCst);
                        assert!(
                            seen >= (e + 1) * p as usize,
                            "pid {pid} passed episode {e} early: {seen}"
                        );
                        b.wait(pid);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), episodes * p as usize);
    }

    #[test]
    fn flat_barrier_correct() {
        for p in [1, 2, 3, 5, 8] {
            stress(Arc::new(FlatBarrier::new(p)), p, 20);
        }
    }

    #[test]
    fn dissemination_barrier_correct() {
        for p in [1, 2, 3, 4, 7, 16] {
            stress(Arc::new(DisseminationBarrier::new(p)), p, 20);
        }
    }

    #[test]
    fn auto_barrier_correct_both_regimes() {
        stress(Arc::new(AutoBarrier::new(4)), 4, 10);
        stress(Arc::new(AutoBarrier::new(12)), 12, 10);
    }

    #[test]
    fn dissemination_rounds_are_log_p() {
        assert_eq!(DisseminationBarrier::new(1).critical_rounds(), 0);
        assert_eq!(DisseminationBarrier::new(2).critical_rounds(), 1);
        assert_eq!(DisseminationBarrier::new(8).critical_rounds(), 3);
        assert_eq!(DisseminationBarrier::new(9).critical_rounds(), 4);
        assert_eq!(DisseminationBarrier::new(16).critical_rounds(), 4);
    }

    #[test]
    fn flat_rounds_are_p() {
        assert_eq!(FlatBarrier::new(16).critical_rounds(), 16);
    }

    #[test]
    fn auto_picks_by_size() {
        assert!(matches!(AutoBarrier::new(2), AutoBarrier::Flat(_)));
        assert!(matches!(AutoBarrier::new(32), AutoBarrier::Dissemination(_)));
    }
}
