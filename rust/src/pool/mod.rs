//! The persistent hot-team executor.
//!
//! [`exec`](crate::ctx::exec) prices every SPMD launch at "argument size
//! plus process spawn" (paper §2, Fig. 1): `p` fresh threads, a new fabric,
//! barrier, and memory tables, all torn down on return. That is the right
//! cost model for one long job — and the wrong one for heavy traffic of
//! many small jobs (PageRank queries, FFT requests), where spawn dominates.
//! The paper's own `lpf_hook`/`lpf_init_t` exist precisely so long-lived
//! host frameworks can amortise setup; a [`Pool`] is the same idea turned
//! into an executor:
//!
//! * the `p` worker threads are spawned **once** and parked on a condvar;
//! * the fabric — tuned barrier, sync-plan arenas, outboxes, registration
//!   tables — is built **once** ([`crate::ctx`]'s `TeamState`) and *reset*,
//!   not rebuilt, between jobs ([`crate::fabric::Fabric::reset_for_job`]);
//! * each worker keeps one request-queue slab, recycled across jobs;
//! * jobs are submitted with [`Pool::submit`] (async, returns a
//!   [`JobHandle`]) or [`Pool::exec`] (blocking, same signature and
//!   semantics as the one-shot `ctx::exec`, which is itself sugar over a
//!   transient pool), and served FIFO — an SPMD job owns the whole team.
//!
//! In the steady state a warm job dispatch performs **zero thread spawns**,
//! and on the prepared-job path the dispatch machinery adds **zero heap
//! allocations**: [`Pool::prepare`] allocates a job's plumbing once and
//! [`Pool::run_prepared`] reuses it per dispatch, so only the job's own
//! outputs and non-empty `Args` allocate. `bench_exec --smoke` asserts both
//! with a spawn counter and a counting global allocator on the empty job.
//!
//! # Isolation between jobs
//!
//! A job must observe a context bit-identical *in behaviour* to a fresh
//! `exec`: empty registers at default capacity, zero queue capacity, zeroed
//! `SyncStats`, simulated clocks at 0. The reset path restores all of this
//! while keeping allocations. Slot handles do **not** survive the job
//! boundary: slot generations keep counting across jobs (the epoch-tag
//! invalidation rule, `docs/pool.md`), so a handle leaked from job N
//! resolves to [`LpfError::Illegal`] in job N+1 — never to job N+1's
//! memory. `tests/pool_isolation.rs` pins both properties.
//!
//! # Failure
//!
//! A job in which any process panicked or aborted leaves the fabric's
//! barrier episodes torn; the pool then performs a **cold reset** (rebuilds
//! the `ContextGroup`) before the next job instead of the warm reset. The
//! team's threads survive either way.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::core::{Args, LpfError, Pid, Result};
use crate::ctx::{run_spmd_recycled, Context, ContextGroup, Platform};
use crate::netsim::faults::FaultPlan;
use crate::queue::MsgQueue;

// ---------------------------------------------------------------- job core

/// Completion state of one submission.
enum JobPhase {
    /// Enqueued or running; the submitter may be blocked in `wait`.
    Queued,
    /// Finished (`cancelled` = pool shut down before the job ran).
    Done { cancelled: bool },
}

/// The typed half of a job: per-process output slots plus the completion
/// latch. Shared between the submitter (waits, collects) and the workers
/// (record results) — allocated once per [`PreparedJob`] and reused.
struct JobInner<O> {
    /// One slot per process; `None` until that pid's share finished.
    outs: Vec<Mutex<Option<Result<O>>>>,
    /// Arguments of the current submission (workers clone per process).
    args: Mutex<Args>,
    sync: Mutex<JobPhase>,
    cv: Condvar,
    /// Any process's share failed — the pool cold-resets the team.
    failed: AtomicBool,
    /// The submitter dropped its [`JobHandle`] without `wait`ing: nobody
    /// will ever collect the outputs. A still-queued abandoned job is
    /// retired without executing; a finished one has its result slots
    /// released immediately (they would otherwise sit in the slots until
    /// every reference to the job died).
    abandoned: AtomicBool,
}

impl<O> JobInner<O> {
    fn new(p: Pid) -> Self {
        JobInner {
            outs: (0..p).map(|_| Mutex::new(None)).collect(),
            args: Mutex::new(Args::none()),
            sync: Mutex::new(JobPhase::Done { cancelled: false }),
            cv: Condvar::new(),
            failed: AtomicBool::new(false),
            abandoned: AtomicBool::new(false),
        }
    }

    /// Arm for a new submission. Fails if the previous one has not been
    /// collected yet (a prepared job may only be in flight once at a time).
    fn begin(&self, args: Args) -> Result<()> {
        {
            let mut ph = self.sync.lock().expect("job phase poisoned");
            if matches!(*ph, JobPhase::Queued) {
                return Err(LpfError::Illegal("prepared job is already in flight".into()));
            }
            *ph = JobPhase::Queued;
        }
        *self.args.lock().expect("job args poisoned") = args;
        self.failed.store(false, Ordering::Relaxed);
        self.abandoned.store(false, Ordering::Relaxed);
        for slot in &self.outs {
            *slot.lock().expect("job slot poisoned") = None;
        }
        Ok(())
    }

    /// The handle died without `wait`. Serialised against [`finish`] by the
    /// phase mutex: exactly one of the two observes the other's work and
    /// performs the slot release.
    fn abandon(&self) {
        self.abandoned.store(true, Ordering::Release);
        let ph = self.sync.lock().expect("job phase poisoned");
        if matches!(*ph, JobPhase::Done { .. }) {
            for slot in &self.outs {
                *slot.lock().expect("job slot poisoned") = None;
            }
        }
        // else: still queued or running — `finish` sees the flag and
        // releases the slots when the job retires.
    }

    fn record(&self, pid: Pid, res: Result<O>) {
        if res.is_err() {
            self.failed.store(true, Ordering::Release);
        }
        *self.outs[pid as usize].lock().expect("job slot poisoned") = Some(res);
    }

    /// Block until the submission completed, then collect all outputs in
    /// pid order (first error wins, matching the one-shot `exec`).
    fn wait_collect(&self) -> Result<Vec<O>> {
        let cancelled = {
            let mut ph = self.sync.lock().expect("job phase poisoned");
            loop {
                match *ph {
                    JobPhase::Queued => ph = self.cv.wait(ph).expect("job phase poisoned"),
                    JobPhase::Done { cancelled } => break cancelled,
                }
            }
        };
        if cancelled {
            return Err(LpfError::Fatal("job cancelled: pool shut down before it ran".into()));
        }
        let mut outs = Vec::with_capacity(self.outs.len());
        for slot in &self.outs {
            match slot.lock().expect("job slot poisoned").take() {
                Some(res) => outs.push(res?),
                None => {
                    return Err(LpfError::Fatal("job completed without an output slot".into()))
                }
            }
        }
        Ok(outs)
    }
}

/// What the worker loop needs from a job, type-erased. **Contract:**
/// [`complete`](RunnableJob::complete) is the pool's last touch of the
/// object — a blocking submitter may free the job the moment it returns.
trait RunnableJob: Send + Sync {
    /// Run `pid`'s share of the SPMD function, recording the result.
    fn run(&self, group: &Arc<ContextGroup>, pid: Pid, slab: &mut MsgQueue);
    /// True if any share failed (panic or abort) — forces a cold reset.
    fn failed(&self) -> bool;
    /// True if the submitter dropped its handle without waiting — the pool
    /// samples this **once per dispatch** (install time) and retires the
    /// job without running it.
    fn abandoned(&self) -> bool;
    /// Release the submitter. Last touch (see trait docs).
    fn complete(&self, cancelled: bool);
}

/// An owned (`'static`) job: [`Pool::submit`] / [`Pool::prepare`].
struct OwnedJob<O, F> {
    inner: Arc<JobInner<O>>,
    spmd: F,
}

/// A borrowed job living on the submitter's stack: [`Pool::exec`]. The
/// submitter blocks until `complete`, so the borrow never dangles.
struct BorrowedJob<'f, O, F> {
    inner: JobInner<O>,
    spmd: &'f F,
}

impl<O> JobInner<O> {
    fn run_into<F>(&self, spmd: &F, group: &Arc<ContextGroup>, pid: Pid, slab: &mut MsgQueue)
    where
        F: Fn(&mut Context, Args) -> O,
    {
        let args = self.args.lock().expect("job args poisoned").clone();
        let res = run_spmd_recycled(group.clone(), pid, spmd, args, slab);
        self.record(pid, res);
    }

    fn finish(&self, cancelled: bool) {
        let mut ph = self.sync.lock().expect("job phase poisoned");
        *ph = JobPhase::Done { cancelled };
        if self.abandoned.load(Ordering::Acquire) {
            // Nobody will collect: release the result slots while still
            // holding the phase lock (see `abandon`).
            for slot in &self.outs {
                *slot.lock().expect("job slot poisoned") = None;
            }
        }
        self.cv.notify_all();
    }
}

impl<O, F> RunnableJob for OwnedJob<O, F>
where
    F: Fn(&mut Context, Args) -> O + Send + Sync,
    O: Send,
{
    fn run(&self, group: &Arc<ContextGroup>, pid: Pid, slab: &mut MsgQueue) {
        self.inner.run_into(&self.spmd, group, pid, slab);
    }

    fn failed(&self) -> bool {
        self.inner.failed.load(Ordering::Acquire)
    }

    fn abandoned(&self) -> bool {
        self.inner.abandoned.load(Ordering::Acquire)
    }

    fn complete(&self, cancelled: bool) {
        self.inner.finish(cancelled);
    }
}

impl<O, F> RunnableJob for BorrowedJob<'_, O, F>
where
    F: Fn(&mut Context, Args) -> O + Sync,
    O: Send,
{
    fn run(&self, group: &Arc<ContextGroup>, pid: Pid, slab: &mut MsgQueue) {
        self.inner.run_into(self.spmd, group, pid, slab);
    }

    fn failed(&self) -> bool {
        self.inner.failed.load(Ordering::Acquire)
    }

    fn abandoned(&self) -> bool {
        // the `Pool::exec` submitter is blocked in `wait_collect` for the
        // job's whole life — it cannot abandon it
        false
    }

    fn complete(&self, cancelled: bool) {
        self.inner.finish(cancelled);
    }
}

/// Type-erased pointer to a [`BorrowedJob`] on a blocked submitter's
/// stack. The pointee stays valid until its `complete` returns (the
/// submitter cannot return from `Pool::exec`, and so cannot free the job,
/// before then); it is held as a *raw* pointer so copies that outlive the
/// job — the worker's binding after `complete`, drained queue entries —
/// are harmless stale pointers, never dangling references.
#[derive(Clone, Copy)]
struct BorrowedPtr(*const dyn RunnableJob);

// SAFETY: the pointee is `Sync` (`RunnableJob: Send + Sync`) and every
// dereference happens before the submitter is released (see `as_job`).
unsafe impl Send for BorrowedPtr {}
unsafe impl Sync for BorrowedPtr {}

/// A queued job: owned (submit/prepared paths) or borrowed from a blocked
/// `Pool::exec` submitter's stack.
#[derive(Clone)]
enum QueuedJob {
    Owned(Arc<dyn RunnableJob>),
    Borrowed(BorrowedPtr),
}

impl QueuedJob {
    fn as_job(&self) -> &dyn RunnableJob {
        match self {
            QueuedJob::Owned(a) => a.as_ref(),
            // SAFETY: only reached before the job's `complete(..)` call
            // returns — `run`/`failed` precede it, and the `complete` call
            // itself is the pool's final touch (trait contract) — so the
            // submitter still owns a live `BorrowedJob`.
            QueuedJob::Borrowed(p) => unsafe { &*p.0 },
        }
    }
}

// ---------------------------------------------------------------- the pool

/// Aggregate pool counters (diagnostics). The queue-wait fields are what
/// the serve layer's SLO tracker consumes: they separate "time spent
/// behind other jobs" from the jobs' own service time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Jobs fully served (including failed ones).
    pub jobs_completed: u64,
    /// Jobs after which the team needed a cold rebuild (failed jobs).
    pub cold_resets: u64,
    /// Jobs waiting in the queue right now (sampled by [`Pool::stats`];
    /// excludes the job currently running).
    pub queue_depth: u64,
    /// High-water mark of `queue_depth` over the pool's lifetime.
    pub max_queue_depth: u64,
    /// Jobs handed to the team so far (each contributes one queue-wait
    /// sample; a job installed on an idle team waits 0 ns).
    pub jobs_dispatched: u64,
    /// Total enqueue→dispatch wait across dispatched jobs, nanoseconds.
    pub queue_wait_ns_total: u64,
    /// Worst single enqueue→dispatch wait, nanoseconds.
    pub queue_wait_ns_max: u64,
}

impl PoolStats {
    /// Mean enqueue→dispatch wait in nanoseconds (NaN before any job).
    pub fn mean_queue_wait_ns(&self) -> f64 {
        if self.jobs_dispatched == 0 {
            return f64::NAN;
        }
        self.queue_wait_ns_total as f64 / self.jobs_dispatched as f64
    }
}

struct PoolState {
    /// The warm team. Replaced (cold reset) only after a failed job.
    group: Arc<ContextGroup>,
    /// Waiting jobs with their enqueue instants (for queue-wait stats).
    queue: VecDeque<(QueuedJob, Instant)>,
    /// Job every worker must run exactly once per `seq` bump.
    current: Option<QueuedJob>,
    /// Decided once, at install time, for the whole team: an owned job
    /// whose handle was already dropped is retired without executing. The
    /// decision must be per-dispatch, not per-worker — workers checking a
    /// live flag independently could split (some entering the job's
    /// barriers, some not) and wedge the team.
    current_skip: bool,
    seq: u64,
    /// Workers still inside `current`.
    running: Pid,
    stats: PoolStats,
    shutdown: bool,
    /// Installed fault-injection plan, re-installed on every cold rebuild
    /// so one-shot faults stay exhausted after the failure they caused.
    fault_plan: Option<Arc<FaultPlan>>,
    /// Protocol-tier configuration, re-applied on every cold rebuild: the
    /// fitted eager/rendezvous crossover belongs to the machine, not to
    /// one team incarnation. `None` keeps the fabric's default
    /// (all-rendezvous).
    protocol: Option<crate::fabric::ProtocolConfig>,
}

struct Shared {
    platform: Platform,
    p: Pid,
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    worker_cv: Condvar,
}

/// A persistent team of `p` SPMD worker processes serving a FIFO queue of
/// jobs over one warm fabric. See the module docs for the cost model and
/// the isolation guarantees.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn a team of `p.max(1)` processes over `platform`. The barrier is
    /// auto-tuned once per process count at startup
    /// ([`crate::barrier::ensure_tuned`]); the chosen episode structure is
    /// then reused by every job the team serves.
    pub fn new(platform: Platform, p: Pid) -> Pool {
        crate::barrier::ensure_tuned(p.max(1));
        Pool::new_untuned(platform, p)
    }

    /// [`Pool::new`] without the startup barrier calibration — the one-shot
    /// `exec` sugar uses this: a transient single-job pool would throw the
    /// measurement away with the pool, so it keeps the old `exec`'s O(p)
    /// barrier heuristic (a persistent pool created later still tunes).
    pub(crate) fn new_untuned(platform: Platform, p: Pid) -> Pool {
        let p = p.max(1);
        let shared = Arc::new(Shared {
            platform: platform.clone(),
            p,
            state: Mutex::new(PoolState {
                group: ContextGroup::new(platform, p),
                queue: VecDeque::with_capacity(16),
                current: None,
                current_skip: false,
                seq: 0,
                running: 0,
                stats: PoolStats::default(),
                shutdown: false,
                fault_plan: None,
                protocol: None,
            }),
            worker_cv: Condvar::new(),
        });
        let workers = (0..p)
            .map(|pid| {
                let shared = shared.clone();
                crate::util::spawn_counted(move || worker_loop(&shared, pid))
            })
            .collect();
        Pool { shared, workers }
    }

    /// Number of processes every job runs on.
    pub fn p(&self) -> Pid {
        self.shared.p
    }

    /// The platform the team's fabric is built on.
    pub fn platform(&self) -> &Platform {
        &self.shared.platform
    }

    /// Aggregate counters (jobs served, cold resets after failures,
    /// queue depth and enqueue→dispatch waits).
    pub fn stats(&self) -> PoolStats {
        let st = self.shared.state.lock().expect("pool poisoned");
        let mut stats = st.stats;
        stats.queue_depth = st.queue.len() as u64;
        stats
    }

    /// Install (or clear) a deterministic fault-injection plan on the
    /// team (see [`crate::netsim::faults`]). The plan survives both warm
    /// resets (its per-job counters restart) and cold rebuilds (the
    /// rebuilt fabric consults the same plan object, so a one-shot fault
    /// that already fired stays exhausted — the team recovers cleanly).
    /// Call between jobs; the fault machinery is for adversarial testing,
    /// not production dispatch.
    pub fn set_fault_plan(&self, plan: Option<Arc<FaultPlan>>) {
        let mut st = self.shared.state.lock().expect("pool poisoned");
        st.group.fabric().set_fault_plan(plan.clone());
        st.fault_plan = plan;
    }

    /// Install the protocol-tier configuration this pool's fabric (and
    /// any fabric a cold rebuild constructs) classifies descriptors with:
    /// the `probe`-fitted eager/rendezvous crossover, or a forced policy
    /// for ablation. Survives warm resets (the fabric keeps it) and cold
    /// rebuilds (re-applied here, like the fault plan). Call between
    /// jobs.
    pub fn set_protocol(&self, cfg: crate::fabric::ProtocolConfig) {
        let mut st = self.shared.state.lock().expect("pool poisoned");
        st.group.fabric().set_protocol(cfg);
        st.protocol = Some(cfg);
    }

    fn enqueue(&self, job: QueuedJob) {
        let mut st = self.shared.state.lock().expect("pool poisoned");
        debug_assert!(!st.shutdown, "enqueue after shutdown");
        if st.current.is_none() {
            // idle team: installed immediately, queue-wait is zero
            st.current_skip = job.as_job().abandoned();
            st.current = Some(job);
            st.seq += 1;
            st.running = self.shared.p;
            st.stats.jobs_dispatched += 1;
            self.shared.worker_cv.notify_all();
        } else {
            st.queue.push_back((job, Instant::now()));
            let depth = st.queue.len() as u64;
            st.stats.max_queue_depth = st.stats.max_queue_depth.max(depth);
        }
    }

    /// Submit an owned SPMD job; returns immediately with a [`JobHandle`].
    /// Jobs are served FIFO — one at a time, each owning the whole team.
    pub fn submit<O, F>(&self, spmd: F, args: Args) -> JobHandle<O>
    where
        F: Fn(&mut Context, Args) -> O + Send + Sync + 'static,
        O: Send + 'static,
    {
        let prepared = self.prepare(spmd);
        prepared.inner.begin(args).expect("fresh job cannot be in flight");
        self.enqueue(QueuedJob::Owned(prepared.erased.clone()));
        JobHandle { inner: Some(prepared.inner) }
    }

    /// Allocate a reusable job once; [`Pool::run_prepared`] then dispatches
    /// it without any heap allocation — the hot path for high-rate small
    /// jobs, and the path `bench_exec --smoke`'s zero-allocation assertion
    /// measures.
    pub fn prepare<O, F>(&self, spmd: F) -> PreparedJob<O>
    where
        F: Fn(&mut Context, Args) -> O + Send + Sync + 'static,
        O: Send + 'static,
    {
        let inner = Arc::new(JobInner::new(self.shared.p));
        let erased: Arc<dyn RunnableJob> = Arc::new(OwnedJob { inner: inner.clone(), spmd });
        PreparedJob { inner, erased }
    }

    /// Dispatch a prepared job and block for its outputs. Steady state: the
    /// dispatch machinery performs zero heap allocations and zero thread
    /// spawns (outputs and non-empty `Args` allocate what they themselves
    /// need, nothing more).
    pub fn run_prepared<O: Send>(&self, job: &PreparedJob<O>, args: Args) -> Result<Vec<O>> {
        if job.inner.outs.len() != self.shared.p as usize {
            // A foreign job would index out of the output table inside a
            // worker thread — reject it before it can wedge the team.
            return Err(LpfError::Illegal(format!(
                "prepared job is for p = {}, this pool has p = {}",
                job.inner.outs.len(),
                self.shared.p
            )));
        }
        job.inner.begin(args)?;
        self.enqueue(QueuedJob::Owned(job.erased.clone()));
        job.inner.wait_collect()
    }

    /// Run one SPMD job to completion — the drop-in equivalent of the
    /// one-shot [`crate::ctx::exec`] on a warm team: same closure bounds
    /// (borrows allowed), same output and error semantics, no spawn.
    pub fn exec<O, F>(&self, spmd: F, args: Args) -> Result<Vec<O>>
    where
        F: Fn(&mut Context, Args) -> O + Sync,
        O: Send,
    {
        let job = BorrowedJob { inner: JobInner::new(self.shared.p), spmd: &spmd };
        job.inner.begin(args).expect("fresh job cannot be in flight");
        // SAFETY (of the later dereferences in `as_job`): `job` lives on
        // this stack frame, and `wait_collect` below blocks until the
        // pool's final touch of it (`complete`, see `RunnableJob`) — by the
        // time this frame can be freed, the pool only retains stale raw
        // pointers it will never dereference. `Pool::drop` likewise
        // completes (cancels) still-queued jobs while their submitters are
        // parked in `wait_collect`.
        let ptr = {
            let erased: &dyn RunnableJob = &job;
            // lifetime-erase the reference, then immediately demote it to a
            // raw pointer (the `&'static` exists only on this line, while
            // the pointee is certainly alive)
            let erased = unsafe {
                std::mem::transmute::<&dyn RunnableJob, &'static dyn RunnableJob>(erased)
            };
            BorrowedPtr(erased as *const dyn RunnableJob)
        };
        self.enqueue(QueuedJob::Borrowed(ptr));
        job.inner.wait_collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        let drained: Vec<(QueuedJob, Instant)> = {
            let mut st = self.shared.state.lock().expect("pool poisoned");
            st.shutdown = true;
            self.shared.worker_cv.notify_all();
            st.queue.drain(..).collect()
        };
        // Cancel jobs that never started (their submitters get an error).
        // The current job, if any, runs to completion first — workers only
        // exit once it is done.
        for (job, _) in &drained {
            job.as_job().complete(true);
        }
        drop(drained);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, pid: Pid) {
    // The per-process request-queue slab, recycled across every job this
    // worker serves (no queue allocation on the warm path).
    let mut slab = MsgQueue::new();
    let mut last_seq = 0u64;
    loop {
        let (job, group, seq, skip) = {
            let mut st = shared.state.lock().expect("pool poisoned");
            loop {
                if let Some(cur) = &st.current {
                    if st.seq != last_seq {
                        break (cur.clone(), st.group.clone(), st.seq, st.current_skip);
                    }
                }
                if st.shutdown {
                    return;
                }
                st = shared.worker_cv.wait(st).expect("pool poisoned");
            }
        };
        last_seq = seq;
        if !skip {
            job.as_job().run(&group, pid, &mut slab);
        }
        // (an abandoned job is retired below without running: its outputs
        // are unobservable, and the skip decision was made at install time
        // so the whole team agrees — no half-entered barriers)

        let mut st = shared.state.lock().expect("pool poisoned");
        st.running -= 1;
        if st.running > 0 {
            continue;
        }
        // Last process out: retire the job, then prepare the team for the
        // next one *before* releasing the submitter — when `wait` returns,
        // the team is already pristine.
        st.stats.jobs_completed += 1;
        if job.as_job().failed() || !group.healthy() {
            // Torn barrier episodes cannot be reused: cold reset. The
            // worker threads themselves stay.
            st.group = ContextGroup::new(shared.platform.clone(), shared.p);
            st.group.fabric().set_fault_plan(st.fault_plan.clone());
            if let Some(cfg) = st.protocol {
                st.group.fabric().set_protocol(cfg);
            }
            st.stats.cold_resets += 1;
        } else {
            group.reset_for_job();
        }
        st.current = match st.queue.pop_front() {
            Some((next, enqueued)) => {
                let wait = enqueued.elapsed().as_nanos() as u64;
                st.stats.jobs_dispatched += 1;
                st.stats.queue_wait_ns_total += wait;
                st.stats.queue_wait_ns_max = st.stats.queue_wait_ns_max.max(wait);
                st.current_skip = next.as_job().abandoned();
                Some(next)
            }
            None => None,
        };
        if st.current.is_some() {
            st.seq += 1;
            st.running = shared.p;
            shared.worker_cv.notify_all();
        }
        drop(st);
        // Final touch: after this the job object may be freed.
        job.as_job().complete(false);
    }
}

// ---------------------------------------------------------------- handles

/// Handle to a job submitted with [`Pool::submit`].
///
/// Dropping the handle without [`wait`](JobHandle::wait) *abandons* the
/// job: a still-queued job is retired by the pool without executing, a
/// finished one has its result slots released immediately, and the workers
/// never block on the dead submitter (completion is a broadcast, not a
/// rendezvous). Abandoning is not cancellation — a job already running
/// runs to completion, its outputs are simply discarded.
#[must_use = "wait() observes the job's outcome"]
pub struct JobHandle<O> {
    /// `Some` until consumed by `wait` (so `Drop` knows to abandon).
    inner: Option<Arc<JobInner<O>>>,
}

impl<O> JobHandle<O> {
    /// Block until the job completed; outputs in pid order, first error
    /// wins — identical to the one-shot `exec`'s return contract.
    pub fn wait(mut self) -> Result<Vec<O>> {
        let inner = self.inner.take().expect("handle waited twice");
        inner.wait_collect()
    }
}

impl<O> Drop for JobHandle<O> {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            inner.abandon();
        }
    }
}

/// A reusable job allocated once by [`Pool::prepare`]: repeated
/// [`Pool::run_prepared`] dispatches add no dispatch-side heap allocation
/// (the job's outputs and non-empty `Args` allocate what they need). Only
/// valid on a pool with the same `p` as the one that prepared it.
pub struct PreparedJob<O> {
    inner: Arc<JobInner<O>>,
    erased: Arc<dyn RunnableJob>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{MSG_DEFAULT, SYNC_DEFAULT};

    fn pool(p: Pid) -> Pool {
        Pool::new(Platform::shared().checked(true), p)
    }

    #[test]
    fn exec_on_pool_matches_one_shot_semantics() {
        let pool = pool(4);
        let outs = pool.exec(|ctx, _| (ctx.pid(), ctx.p()), Args::none()).unwrap();
        assert_eq!(outs, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
    }

    #[test]
    fn jobs_queue_fifo_and_all_complete() {
        let pool = pool(2);
        let handles: Vec<JobHandle<u32>> = (0..8u32)
            .map(|k| pool.submit(move |ctx, _| ctx.pid() + 100 * k, Args::none()))
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![100 * k as u32, 100 * k as u32 + 1]);
        }
        assert_eq!(pool.stats().jobs_completed, 8);
        assert_eq!(pool.stats().cold_resets, 0);
    }

    #[test]
    fn warm_jobs_communicate_like_fresh_contexts() {
        let pool = pool(4);
        for round in 0..5u32 {
            let outs = pool
                .exec(
                    |ctx, args| {
                        ctx.resize_memory_register(2).unwrap();
                        ctx.resize_message_queue(ctx.p() as usize).unwrap();
                        ctx.sync(SYNC_DEFAULT).unwrap();
                        let mine = ctx.register_global(4).unwrap();
                        let all = ctx.register_global(4 * ctx.p() as usize).unwrap();
                        ctx.write_typed(mine, 0, &[ctx.pid() + args.input[0] as u32]).unwrap();
                        for k in 0..ctx.p() {
                            ctx.put(mine, 0, k, all, 4 * ctx.pid() as usize, 4, MSG_DEFAULT)
                                .unwrap();
                        }
                        ctx.sync(SYNC_DEFAULT).unwrap();
                        let mut v = vec![0u32; ctx.p() as usize];
                        ctx.read_typed(all, 0, &mut v).unwrap();
                        v.iter().sum::<u32>()
                    },
                    Args::input(vec![round as u8]),
                )
                .unwrap();
            let want = (0..4).map(|s| s + round).sum::<u32>();
            assert!(outs.iter().all(|&x| x == want), "round {round}: {outs:?}");
        }
    }

    #[test]
    fn prepared_job_is_reusable() {
        let pool = pool(3);
        let job = pool.prepare(|ctx, _| ctx.pid() * 2);
        for _ in 0..10 {
            assert_eq!(pool.run_prepared(&job, Args::none()).unwrap(), vec![0, 2, 4]);
        }
    }

    #[test]
    fn prepared_job_rejected_on_pool_with_different_p() {
        let small = pool(2);
        let big = pool(4);
        let job = small.prepare(|ctx, _| ctx.pid());
        let err = big.run_prepared(&job, Args::none()).unwrap_err();
        assert!(matches!(&err, LpfError::Illegal(m) if m.contains("p = 2")), "{err:?}");
        // the job itself is untouched and still runs on its own pool
        assert_eq!(small.run_prepared(&job, Args::none()).unwrap(), vec![0, 1]);
    }

    #[test]
    fn failed_job_cold_resets_and_team_survives() {
        let pool = pool(2);
        let res = pool.exec(
            |ctx, _| {
                if ctx.pid() == 1 {
                    panic!("deliberate test panic");
                }
                ctx.resize_message_queue(1).unwrap();
                let _ = ctx.sync(SYNC_DEFAULT);
            },
            Args::none(),
        );
        let err = format!("{:?}", res.unwrap_err());
        assert!(err.contains("deliberate test panic"), "payload propagated: {err}");
        assert!(err.contains("pid 1"), "pid included: {err}");
        // the next job runs on a cold-rebuilt team, as if nothing happened
        let outs = pool.exec(|ctx, _| ctx.pid(), Args::none()).unwrap();
        assert_eq!(outs, vec![0, 1]);
        assert_eq!(pool.stats().cold_resets, 1);
    }

    #[test]
    fn drop_cancels_queued_jobs() {
        let pool = pool(2);
        // a slow job keeps the team busy so the second one stays queued
        let slow = pool.submit(
            |_ctx, _| std::thread::sleep(std::time::Duration::from_millis(50)),
            Args::none(),
        );
        let queued: JobHandle<u32> = pool.submit(|ctx, _| ctx.pid(), Args::none());
        drop(pool);
        // the in-flight job completed; the queued one may have run (if it
        // was installed before shutdown) or been cancelled — both are
        // valid; what must not happen is a hang or a wrong result.
        slow.wait().unwrap();
        match queued.wait() {
            Ok(v) => assert_eq!(v, vec![0, 1]),
            Err(LpfError::Fatal(m)) => assert!(m.contains("cancelled"), "{m}"),
            Err(e) => panic!("unexpected error {e:?}"),
        }
    }

    #[test]
    fn pool_stats_track_queue_depth_and_wait() {
        let pool = pool(2);
        let slow = pool.submit(
            |_ctx, _| std::thread::sleep(std::time::Duration::from_millis(20)),
            Args::none(),
        );
        let h1: JobHandle<u32> = pool.submit(|ctx, _| ctx.pid(), Args::none());
        let h2: JobHandle<u32> = pool.submit(|ctx, _| ctx.pid(), Args::none());
        let mid = pool.stats();
        assert_eq!(mid.queue_depth, 2, "two jobs parked behind the slow one");
        assert!(mid.max_queue_depth >= 2);
        assert_eq!(mid.jobs_dispatched, 1, "only the slow job was installed");
        slow.wait().unwrap();
        h1.wait().unwrap();
        h2.wait().unwrap();
        let done = pool.stats();
        assert_eq!(done.queue_depth, 0);
        assert_eq!(done.jobs_dispatched, 3);
        assert!(done.queue_wait_ns_total > 0, "queued jobs waited behind the slow one");
        assert!(done.mean_queue_wait_ns() > 0.0);
        assert!(done.queue_wait_ns_max as f64 >= done.mean_queue_wait_ns());
    }

    #[test]
    fn dropped_handle_skips_queued_job_and_releases_slots() {
        struct Guard(Arc<std::sync::atomic::AtomicU64>);
        impl Drop for Guard {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }

        let pool = pool(2);
        let ran = Arc::new(AtomicBool::new(false));
        let drops = Arc::new(std::sync::atomic::AtomicU64::new(0));

        // occupy the team so the victim is still queued when abandoned
        let slow = pool.submit(
            |_ctx, _| std::thread::sleep(std::time::Duration::from_millis(30)),
            Args::none(),
        );
        let victim = {
            let ran = ran.clone();
            let drops = drops.clone();
            pool.submit(
                move |_ctx, _| {
                    ran.store(true, Ordering::SeqCst);
                    Guard(drops.clone())
                },
                Args::none(),
            )
        };
        drop(victim); // dropped without wait(): abandoned
        slow.wait().unwrap();
        // FIFO: this only runs after the abandoned job was retired, and the
        // workers got here without blocking on the dead submitter
        let outs = pool.exec(|ctx, _| ctx.pid(), Args::none()).unwrap();
        assert_eq!(outs, vec![0, 1]);
        assert!(!ran.load(Ordering::SeqCst), "abandoned queued job must not execute");
        assert_eq!(drops.load(Ordering::SeqCst), 0, "no outputs were ever produced");
        assert_eq!(pool.stats().jobs_completed, 3, "abandoned job retired exactly once");

        // abandoned *after* completion: the parked results are released by
        // the handle drop, not leaked until some later reuse
        let h = {
            let drops = drops.clone();
            pool.submit(move |_ctx, _| Guard(drops.clone()), Args::none())
        };
        pool.exec(|_ctx, _| (), Args::none()).unwrap(); // FIFO fence: job finished
        assert_eq!(drops.load(Ordering::SeqCst), 0, "outputs parked in the result slots");
        drop(h);
        assert_eq!(drops.load(Ordering::SeqCst), 2, "drop released both result slots");
    }

    #[test]
    fn concurrent_submitters_serialise() {
        let pool = Arc::new(pool(2));
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let pool = pool.clone();
                s.spawn(move || {
                    for _ in 0..5 {
                        let outs =
                            pool.exec(move |ctx, _| ctx.pid() + t, Args::none()).unwrap();
                        assert_eq!(outs, vec![t, t + 1]);
                    }
                });
            }
        });
        assert_eq!(pool.stats().jobs_completed, 20);
    }
}
