//! Property tests: random h-relations must be delivered byte-exactly and
//! identically on every backend, matching a sequential-replay oracle.
//!
//! (The offline registry has no proptest; `util::rng::XorShift64` drives a
//! seeded generator loop — failures print the seed for replay.)

use lpf::core::{Args, MSG_DEFAULT, SYNC_DEFAULT};
use lpf::ctx::{exec, Platform, Root};
use lpf::util::rng::XorShift64;

const SLOT_BYTES: usize = 96;

/// A randomly generated superstep: per pid, a list of puts and gets.
#[derive(Debug, Clone)]
struct Scenario {
    p: u32,
    /// (src_pid, src_off, dst_pid, dst_off, len), issued in order per src.
    puts: Vec<(u32, usize, u32, usize, usize)>,
    /// (issuer, src_pid, src_off, dst_off, len)
    gets: Vec<(u32, u32, usize, usize, usize)>,
}

/// Generate a legal random scenario: writes land in [0, 48), reads come
/// from [48, 96) — read/write disjoint by construction (LPF legality).
fn gen_scenario(rng: &mut XorShift64) -> Scenario {
    let p = 2 + rng.below(4) as u32; // 2..=5
    let n_puts = rng.below_usize(12);
    let n_gets = rng.below_usize(6);
    let half = SLOT_BYTES / 2;
    let mut puts = Vec::new();
    for _ in 0..n_puts {
        let src = rng.below(p as u64) as u32;
        let dst = rng.below(p as u64) as u32;
        let len = 1 + rng.below_usize(24);
        let src_off = half + rng.below_usize(half - len);
        let dst_off = rng.below_usize(half - len);
        puts.push((src, src_off, dst, dst_off, len));
    }
    let mut gets = Vec::new();
    for _ in 0..n_gets {
        let issuer = rng.below(p as u64) as u32;
        let src = rng.below(p as u64) as u32;
        let len = 1 + rng.below_usize(24);
        let src_off = half + rng.below_usize(half - len);
        let dst_off = rng.below_usize(half - len);
        gets.push((issuer, src, src_off, dst_off, len));
    }
    Scenario { p, puts, gets }
}

/// Initial slot contents for a pid: deterministic pattern.
fn initial(pid: u32) -> Vec<u8> {
    (0..SLOT_BYTES).map(|i| (pid as usize * 37 + i * 11) as u8).collect()
}

/// Sequential oracle: apply all writes in (writer pid, seq) order.
fn oracle(sc: &Scenario) -> Vec<Vec<u8>> {
    let mut mem: Vec<Vec<u8>> = (0..sc.p).map(initial).collect();
    // per-issuer sequence: puts and gets interleaved in issue order — here
    // all puts then gets per pid, matching the SPMD program below.
    #[derive(Clone)]
    struct W {
        writer: u32,
        seq: u32,
        dst: u32,
        dst_off: usize,
        data: Vec<u8>,
    }
    let mut writes: Vec<W> = Vec::new();
    let mut seqs = vec![0u32; sc.p as usize];
    for &(src, src_off, dst, dst_off, len) in &sc.puts {
        let data = mem[src as usize][src_off..src_off + len].to_vec();
        writes.push(W { writer: src, seq: seqs[src as usize], dst, dst_off, data });
        seqs[src as usize] += 1;
    }
    for &(issuer, src, src_off, dst_off, len) in &sc.gets {
        let data = mem[src as usize][src_off..src_off + len].to_vec();
        writes.push(W { writer: issuer, seq: seqs[issuer as usize], dst: issuer, dst_off, data });
        seqs[issuer as usize] += 1;
    }
    writes.sort_by_key(|w| ((w.writer as u64) << 32) | w.seq as u64);
    for w in writes {
        mem[w.dst as usize][w.dst_off..w.dst_off + w.data.len()].copy_from_slice(&w.data);
    }
    mem
}

/// Execute the scenario on one platform, returning final slot contents.
fn run_on(sc: &Scenario, plat: Platform) -> Vec<Vec<u8>> {
    let sc = sc.clone();
    let root = Root::new(plat).with_max_procs(sc.p);
    exec(
        &root,
        sc.p,
        move |ctx, _| {
            ctx.resize_memory_register(1).unwrap();
            ctx.resize_message_queue(64).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let slot = ctx.register_global(SLOT_BYTES).unwrap();
            ctx.write_slot(slot, 0, &initial(ctx.pid())).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap(); // all initialised
            for &(src, src_off, dst, dst_off, len) in &sc.puts {
                if src == ctx.pid() {
                    ctx.put(slot, src_off, dst, slot, dst_off, len, MSG_DEFAULT).unwrap();
                }
            }
            for &(issuer, src, src_off, dst_off, len) in &sc.gets {
                if issuer == ctx.pid() {
                    ctx.get(src, slot, src_off, slot, dst_off, len, MSG_DEFAULT).unwrap();
                }
            }
            ctx.sync(SYNC_DEFAULT).unwrap();
            let mut out = vec![0u8; SLOT_BYTES];
            ctx.read_slot(slot, 0, &mut out).unwrap();
            out
        },
        Args::none(),
    )
    .unwrap()
}

#[test]
fn random_h_relations_match_oracle_on_all_backends() {
    let mut rng = XorShift64::new(0x5EED_2026);
    for case in 0..40 {
        let sc = gen_scenario(&mut rng);
        let want = oracle(&sc);
        for (name, plat) in [
            ("shared", Platform::shared().checked(false)),
            ("rdma", Platform::rdma()),
            ("msg", Platform::msg()),
            ("hybrid", Platform::hybrid(2)),
        ] {
            let got = run_on(&sc, plat);
            assert_eq!(
                got, want,
                "case {case} backend {name} diverged from oracle; scenario: {sc:?}"
            );
        }
    }
}

#[test]
fn conflict_free_attr_equivalent_when_no_conflicts() {
    // when a scenario happens to be conflict-free, assume_no_conflicts
    // must give identical bytes (it skips resolution, lowering g)
    let mut rng = XorShift64::new(77);
    let mut tested = 0;
    for _ in 0..60 {
        let sc = gen_scenario(&mut rng);
        // keep only scenarios with no overlapping destination writes
        let mut intervals: Vec<(u32, usize, usize)> = Vec::new();
        let mut ok = true;
        let mut add = |dst: u32, off: usize, len: usize, ok: &mut bool| {
            for &(d, o, l) in intervals.iter() {
                if d == dst && off < o + l && o < off + len {
                    *ok = false;
                }
            }
            intervals.push((dst, off, len));
        };
        for &(_, _, dst, dst_off, len) in &sc.puts {
            add(dst, dst_off, len, &mut ok);
        }
        for &(issuer, _, _, dst_off, len) in &sc.gets {
            add(issuer, dst_off, len, &mut ok);
        }
        if !ok {
            continue;
        }
        tested += 1;
        let want = oracle(&sc);
        let sc2 = sc.clone();
        let root = Root::new(Platform::shared().checked(false)).with_max_procs(sc.p);
        let got = exec(
            &root,
            sc.p,
            move |ctx, _| {
                ctx.resize_memory_register(1).unwrap();
                ctx.resize_message_queue(64).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                let slot = ctx.register_global(SLOT_BYTES).unwrap();
                ctx.write_slot(slot, 0, &initial(ctx.pid())).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                for &(src, src_off, dst, dst_off, len) in &sc2.puts {
                    if src == ctx.pid() {
                        ctx.put(slot, src_off, dst, slot, dst_off, len, MSG_DEFAULT).unwrap();
                    }
                }
                for &(issuer, src, src_off, dst_off, len) in &sc2.gets {
                    if issuer == ctx.pid() {
                        ctx.get(src, slot, src_off, slot, dst_off, len, MSG_DEFAULT).unwrap();
                    }
                }
                ctx.sync(lpf::core::SyncAttr { assume_no_conflicts: true }).unwrap();
                let mut out = vec![0u8; SLOT_BYTES];
                ctx.read_slot(slot, 0, &mut out).unwrap();
                out
            },
            Args::none(),
        )
        .unwrap();
        assert_eq!(got, want);
    }
    assert!(tested >= 3, "want several conflict-free scenarios, got {tested}");
}
