//! End-to-end application tests over the full three-layer stack:
//! the immortal BSP FFT and the accelerated PageRank with their
//! process-local compute on PJRT artifacts (skips if not built).

use lpf::bsplib::Bsp;
use lpf::core::Args;
use lpf::ctx::{exec, Platform, Root};
use lpf::fft::bsp::{Backend, BspFft};
use lpf::fft::local;
use lpf::fft::plan::FftPlan;
use lpf::graphblas::{pagerank_serial, Compute};
use lpf::graphgen::cage_like;
use lpf::runtime::Runtime;
use lpf::sparksim::pagerank::accelerated_pagerank;
use lpf::sparksim::Spark;
use lpf::util::rng::XorShift64;

fn runtime() -> Option<std::sync::Arc<Runtime>> {
    match Runtime::global() {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("SKIP apps_e2e: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn bsp_fft_with_artifacts_matches_serial() {
    let Some(rt) = runtime() else { return };
    let p: u32 = 4;
    let n: usize = 1 << 12; // artifacts built for k = 10..=18
    let mut rng = XorShift64::new(31);
    let g_re: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
    let g_im: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
    let plan = FftPlan::new(n).unwrap();
    let (want_re, want_im) = local::fft(&plan, &g_re, &g_im).unwrap();

    let root = Root::new(Platform::shared()).with_max_procs(p);
    let (re2, im2) = (g_re.clone(), g_im.clone());
    let outs = exec(
        &root,
        p,
        move |ctx, _| {
            let r = ctx.pid();
            let pp = ctx.p();
            let m = n / pp as usize;
            let mut bsp = Bsp::begin(ctx, 8, 4 * pp as usize + 8).unwrap();
            bsp.sync().unwrap();
            let mut fft = BspFft::new(&mut bsp, n, Backend::Artifacts(rt.clone())).unwrap();
            bsp.sync().unwrap();
            let re: Vec<f32> = (0..m).map(|j| re2[r as usize + pp as usize * j]).collect();
            let im: Vec<f32> = (0..m).map(|j| im2[r as usize + pp as usize * j]).collect();
            let (o_re, o_im) = fft.run(&mut bsp, &re, &im).unwrap();
            let blk = m / pp as usize;
            let mut triples = Vec::new();
            for k2 in 0..blk {
                for k1 in 0..pp as usize {
                    triples.push((
                        fft.global_index(k2, k1),
                        o_re[k2 * pp as usize + k1],
                        o_im[k2 * pp as usize + k1],
                    ));
                }
            }
            bsp.end().unwrap();
            triples
        },
        Args::none(),
    )
    .unwrap();
    let tol = 1e-2 * (n as f32).sqrt();
    for triples in outs {
        for (gidx, re, im) in triples {
            assert!((re - want_re[gidx]).abs() < tol, "re[{gidx}]: {re} vs {}", want_re[gidx]);
            assert!((im - want_im[gidx]).abs() < tol, "im[{gidx}]");
        }
    }
}

#[test]
fn accelerated_pagerank_with_artifacts_matches_serial() {
    let Some(rt) = runtime() else { return };
    // cage-like graphs are low-skew: blocks fit the aot shape 8n/p
    let n = 1 << 13;
    let workers = 4;
    let g = cage_like(n, 3, 99);
    let nnz_pad = 8 * n / workers;
    // blocks must fit (cage band 3 → ≤ ~4.2 edges per row)
    let rows_per = n.div_ceil(workers);
    let mut per_block = vec![0usize; workers];
    for &(_, d) in &g.edges {
        per_block[(d as usize) / rows_per] += 1;
    }
    assert!(per_block.iter().all(|&b| b <= nnz_pad), "cage blocks must fit aot pad");
    let name = format!("spmv_{}_{}_{}", nnz_pad, n, rows_per);
    assert!(rt.manifest().get(&name).is_some(), "artifact {name} must exist");

    let sc = Spark::new(workers, 8);
    let out = accelerated_pagerank(
        &sc,
        &g,
        Compute::Artifacts(rt.clone()),
        0.85,
        1e-6,
        60,
        nnz_pad,
        "apps-e2e",
    )
    .unwrap();
    let (want, _) = pagerank_serial(&g, 0.85, 1e-6, 60);
    for v in 0..n {
        assert!(
            (out.ranks[v] - want[v]).abs() < 5e-5,
            "rank[{v}]: {} vs {}",
            out.ranks[v],
            want[v]
        );
    }
    let sum: f32 = out.ranks.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3);
}
