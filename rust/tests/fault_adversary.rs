//! The adversary suite (ISSUE 4): fault injection + differential checking.
//!
//! Pins the tentpole's guarantees in `cargo test` (the full seed sweep
//! runs in CI via `bench_faults --smoke`):
//!
//! * the fault-free differential matrix is clean — one SPMD program is
//!   bit-identical on shared / rdma / msg / hybrid / hybrid-fat (the
//!   last two routed over NumaPair and FatTree topologies), cold and
//!   warm, under every protocol-tier policy (forced rendezvous, forced
//!   eager, mixed auto);
//! * injected reportable faults end in a clean `LpfError` of the same
//!   class everywhere, one pool cold-rebuild, and a recovered team;
//! * injected absorbed faults are invisible in memory and statistics;
//! * an injected allocation failure honours the mitigable
//!   no-side-effects contract and is one-shot.

use lpf::check::{classify, differential, run_case, run_case_in, ExecMode, SyncMode};
use lpf::core::{Args, LpfError, SYNC_DEFAULT};
use lpf::ctx::Platform;
use lpf::netsim::faults::{FaultPlan, FaultSpec};
use lpf::pool::Pool;

#[test]
fn no_fault_differential_matrix_is_clean() {
    let r = differential(4, 1, None);
    assert!(r.ok(), "violations: {:#?}", r.violations);
    assert_eq!(r.cases.len(), 60, "5 backends x cold/warm x bulk/split x rdv/eager/auto");
    assert!(r.cases.iter().all(|c| c.class() == "ok" && c.recovered));
}

#[test]
fn seeded_fault_sweep_holds_compliance() {
    // A slice of the CI sweep: every derived fault either absorbs or
    // surfaces cleanly, identically across the matrix.
    for seed in 0..4u64 {
        let r = differential(4, 1, Some(seed));
        assert!(r.ok(), "seed {seed} ({}): {:#?}", r.fault_desc, r.violations);
    }
}

#[test]
fn injected_abort_is_clean_cold_rebuilds_and_recovers() {
    for (name, plat) in
        [("shared", Platform::shared().checked(true)), ("rdma", Platform::rdma().checked(true))]
    {
        // split-phase parks the injected abort at `sync_begin` and must
        // surface it at `sync_end` with the same class as the bulk path
        for sync in [SyncMode::Bulk, SyncMode::Split] {
            let plan = FaultPlan::one(FaultSpec::AbortAtSuperstep { pid: 1, step: 1 });
            let case = run_case_in(name, &plat, 3, 2, ExecMode::Warm, sync, Some(plan.clone()));
            let err = case.result.expect_err("the abort must surface");
            // pid 0 observes its peer's abort; the injected error itself
            // lives on pid 1 — both classes are clean, deterministic
            assert_eq!(classify(&err), "peer-aborted", "{name}/{}: {err:?}", sync.name());
            assert_eq!(case.cold_resets, 1, "{name}: failed job must cold-rebuild the team");
            assert!(case.recovered, "{name}: team must serve the next job");
            assert_eq!(plan.injections(), 1);
        }
    }
}

#[test]
fn injected_register_failure_is_mitigable_and_one_shot() {
    let pool = Pool::new(Platform::shared().checked(true), 1);
    pool.set_fault_plan(Some(FaultPlan::one(FaultSpec::FailSlotRegister { pid: 0, nth: 1 })));
    pool.exec(
        |ctx, _| {
            ctx.resize_memory_register(4).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let a = ctx.register_global(8).unwrap(); // ordinal 0: clean
            let err = ctx.register_global(8).unwrap_err(); // ordinal 1: injected
            assert!(matches!(&err, LpfError::OutOfMemory(m) if m.contains("injected")), "{err:?}");
            assert!(err.is_mitigable());
            // no side effects + one-shot: the retry succeeds and lands on
            // the index the failed call would have taken
            let b = ctx.register_global(8).unwrap();
            assert_eq!(a.index(), 0);
            assert_eq!(b.index(), 1, "failed registration consumed no slot");
        },
        Args::none(),
    )
    .unwrap();
    // a mitigated fault is not a failure: the team stayed warm
    assert_eq!(pool.stats().cold_resets, 0);
}

#[test]
fn absorbed_wire_faults_leave_observations_bit_identical() {
    for (name, plat) in
        [("msg", Platform::msg().checked(true)), ("hybrid", Platform::hybrid(2).checked(true))]
    {
        let clean = run_case(name, &plat, 4, 7, ExecMode::Cold, None);
        let reference = clean.result.expect("clean run");
        for spec in [
            FaultSpec::ReorderArrivals { step: 1 },
            FaultSpec::DelayRendezvous { pid: 2, step: 1, ns: 300_000.0 },
            FaultSpec::DelayMeta { pid: 0, step: 2, ns: 150_000.0 },
        ] {
            // bulk, and split-phase — where the fault lands inside the
            // begin→end window while the process is busy computing. Both
            // must match the *bulk* clean reference bit for bit.
            for sync in [SyncMode::Bulk, SyncMode::Split] {
                let plan = FaultPlan::one(spec);
                let case =
                    run_case_in(name, &plat, 4, 7, ExecMode::Cold, sync, Some(plan.clone()));
                let observed = case.result.expect("absorbed faults must not fail");
                assert_eq!(
                    observed,
                    reference,
                    "{name}/{}: {spec:?} changed memory or stats (must be model-legal)",
                    sync.name()
                );
                assert!(plan.injections() > 0, "{name}: {spec:?} never fired");
                assert_eq!(case.cold_resets, 0);
            }
        }
    }
}

#[test]
fn adversary_exercises_coalescing_and_trimming() {
    // sanity on the workload itself: the CRCW storm trims bytes and the
    // contiguous run coalesces, so the oracle is comparing a pipeline
    // that actually went through every engine phase
    let case = run_case("shared", &Platform::shared().checked(true), 4, 1, ExecMode::Cold, None);
    let obs = case.result.unwrap();
    let total_trimmed: u64 = obs.iter().map(|o| o.stats.bytes_trimmed).sum();
    assert!(total_trimmed > 0, "storm must overlap: {obs:?}");
    let sent: u64 = obs.iter().map(|o| o.stats.msgs_out).sum();
    // per pid: p allgather puts + 1 storm put + 1 coalesced run + 1 get
    assert_eq!(sent, 4 * (4 + 3), "coalescing must collapse the 4-put run");
}
