//! Integration tests for the typed superstep-epoch API (v2).
//!
//! * Property tests: typed-slot round trips for arbitrary Pod element
//!   types, offsets, and lengths must be byte-exact, locally and across a
//!   put/get superstep. (The offline registry has no proptest;
//!   `util::rng::XorShift64` drives a seeded generator loop — failures
//!   print the seed parameters for replay.)
//! * A pin of the `register_global`/`alloc_global` id-alignment contract:
//!   ids align across processes when every process performs the same
//!   sequence of global (de)registrations, and the aligned handle really
//!   does name the peer's corresponding area.
//! * Enqueue-time validation: out-of-range *local* sides of put/get fail
//!   with `Illegal` at the call site, not inside the next sync.

use lpf::core::{Args, LpfError, MSG_DEFAULT, SYNC_DEFAULT};
use lpf::ctx::{exec, Context, Platform, Root, TypedSlot};
use lpf::util::rng::XorShift64;

fn root(p: u32) -> Root {
    Root::new(Platform::shared().checked(true)).with_max_procs(p)
}

// ---------------------------------------------------------------- property

/// One round-trip case for element type T: random slot length, offset and
/// payload; write → read back locally, then put to the peer and compare.
fn roundtrip_case<T>(ctx: &mut Context, rng_seed: u64, mk: impl Fn(&mut XorShift64) -> T)
where
    T: lpf::ctx::Pod + PartialEq + std::fmt::Debug,
{
    let mut rng = XorShift64::new(rng_seed);
    let slot_len = 1 + rng.below_usize(64);
    let n = 1 + rng.below_usize(slot_len);
    let off = rng.below_usize(slot_len - n + 1);
    let data: Vec<T> = (0..n).map(|_| mk(&mut rng)).collect();

    // local round trip at a random offset
    let local: TypedSlot<T> = ctx.alloc_local::<T>(slot_len).unwrap();
    ctx.write(local, off, &data).unwrap();
    let mut back = data.clone();
    ctx.read(local, off, &mut back).unwrap();
    assert_eq!(back, data, "local roundtrip seed {rng_seed}");

    // cross-process round trip: put my range to the peer's mirror slot
    let mirror = ctx.alloc_global::<T>(slot_len).unwrap();
    ctx.sync(SYNC_DEFAULT).unwrap();
    let peer = (ctx.pid() + 1) % ctx.p();
    ctx.superstep(|ep| ep.put_slice(local, off, peer, mirror, off, n)).unwrap();
    // every pid generated the same data (same seed), so the incoming
    // payload equals ours
    let mut got = data.clone();
    ctx.read(mirror, off, &mut got).unwrap();
    assert_eq!(got, data, "put roundtrip seed {rng_seed}");

    // and fetch it back from the peer with a get
    let fetched = ctx.alloc_local::<T>(slot_len).unwrap();
    ctx.superstep(|ep| ep.get_slice(peer, mirror, off, fetched, off, n)).unwrap();
    let mut got2 = data.clone();
    ctx.read(fetched, off, &mut got2).unwrap();
    assert_eq!(got2, data, "get roundtrip seed {rng_seed}");

    ctx.dealloc(fetched).unwrap();
    ctx.dealloc(mirror).unwrap();
    ctx.dealloc(local).unwrap();
    // keep the global-deregistration sequence collective
    ctx.sync(SYNC_DEFAULT).unwrap();
}

#[test]
fn typed_roundtrips_hold_for_arbitrary_pod_types() {
    exec(
        &root(2),
        2,
        |ctx, _| {
            ctx.bootstrap(8, 256).unwrap();
            for case in 0..12u64 {
                let seed = 0xC0FFEE + 977 * case;
                roundtrip_case::<u8>(ctx, seed, |r| r.next_u64() as u8);
                roundtrip_case::<u16>(ctx, seed + 1, |r| r.next_u64() as u16);
                roundtrip_case::<u32>(ctx, seed + 2, |r| r.next_u64() as u32);
                roundtrip_case::<u64>(ctx, seed + 3, |r| r.next_u64());
                roundtrip_case::<i32>(ctx, seed + 4, |r| r.next_u64() as i32);
                roundtrip_case::<f32>(ctx, seed + 5, |r| r.unit_f64() as f32);
                roundtrip_case::<f64>(ctx, seed + 6, |r| r.unit_f64());
            }
        },
        Args::none(),
    )
    .unwrap();
}

#[test]
fn typed_and_raw_apis_interoperate_byte_exactly() {
    // v2 is a layer, not a fork: bytes written through a TypedSlot must be
    // readable through the raw Memslot handle, and vice versa
    exec(
        &root(1),
        1,
        |ctx, _| {
            ctx.bootstrap(2, 2).unwrap();
            let typed = ctx.alloc_local::<u32>(4).unwrap();
            ctx.write(typed, 0, &[0x01020304u32, 0x05060708]).unwrap();
            let mut raw = vec![0u8; 8];
            ctx.read_slot(typed.raw(), 0, &mut raw).unwrap();
            let mut expect = Vec::new();
            expect.extend_from_slice(&0x01020304u32.to_le_bytes());
            expect.extend_from_slice(&0x05060708u32.to_le_bytes());
            assert_eq!(raw, expect);
            // byte 12 is the little-endian low byte of element 3
            ctx.write_slot(typed.raw(), 12, &[0xAA]).unwrap();
            let v = ctx.read_vec(typed).unwrap();
            assert_eq!(v[3], 0xAA);
        },
        Args::none(),
    )
    .unwrap();
}

// ------------------------------------------------------------ id alignment

#[test]
fn global_ids_align_across_processes_under_same_call_order() {
    let outs = exec(
        &root(4),
        4,
        |ctx, _| {
            ctx.bootstrap(8, 4 * ctx.p() as usize).unwrap();
            // interleave local and global registrations: local ids must not
            // perturb the global id sequence (separate id spaces)
            let g1 = ctx.alloc_global::<u64>(1).unwrap();
            let _l1 = ctx.alloc_local::<u64>(3).unwrap();
            let g2 = ctx.alloc_global::<u64>(ctx.p() as usize).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            // deregister + re-register: the freed id must be reused
            // deterministically on every process
            ctx.dealloc(g1).unwrap();
            let g3 = ctx.alloc_global::<u64>(2).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            // the aligned handle names the peer's corresponding area:
            // allgather through g2 using only our own handle
            ctx.write(g3, 0, &[ctx.pid() as u64 + 40, 0]).unwrap();
            ctx.superstep(|ep| {
                for k in 0..ep.p() {
                    ep.put_slice(g3, 0, k, g2, ep.pid() as usize, 1)?;
                }
                Ok(())
            })
            .unwrap();
            let all = ctx.read_vec(g2).unwrap();
            (g2.raw().index(), g3.raw().index(), all)
        },
        Args::none(),
    )
    .unwrap();
    let (g2_idx, g3_idx, ref gathered) = outs[0];
    assert_eq!(gathered, &vec![40, 41, 42, 43]);
    for (pid, (i2, i3, all)) in outs.iter().enumerate() {
        assert_eq!(*i2, g2_idx, "pid {pid}: g2 id misaligned");
        assert_eq!(*i3, g3_idx, "pid {pid}: recycled g3 id misaligned");
        assert_eq!(all, gathered, "pid {pid}: allgather through aligned ids");
    }
}

// ------------------------------------------------- enqueue-time validation

#[test]
fn raw_put_get_validate_local_side_at_enqueue() {
    exec(
        &root(2),
        2,
        |ctx, _| {
            ctx.bootstrap(2, 8).unwrap();
            let s = ctx.register_global(8).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let peer = (ctx.pid() + 1) % 2;

            // put: local source range must fit — caught HERE, not in sync
            let err = ctx.put(s, 4, peer, s, 0, 8, MSG_DEFAULT).unwrap_err();
            assert!(matches!(err, LpfError::Illegal(_)), "got {err:?}");
            // offset+len overflow must not wrap around
            let err = ctx.put(s, usize::MAX, peer, s, 0, 2, MSG_DEFAULT).unwrap_err();
            assert!(matches!(err, LpfError::Illegal(_)));
            // get: local destination range must fit
            let err = ctx.get(peer, s, 0, s, 6, 4, MSG_DEFAULT).unwrap_err();
            assert!(matches!(err, LpfError::Illegal(_)));
            // unknown (stale) slots are rejected at enqueue too
            let stale = ctx.register_local(4).unwrap();
            ctx.deregister(stale).unwrap();
            let err = ctx.put(stale, 0, peer, s, 0, 1, MSG_DEFAULT).unwrap_err();
            assert!(matches!(err, LpfError::Illegal(_)));

            // nothing was queued by any failed call: the next superstep
            // must complete cleanly and deliver only the legal message
            ctx.write_slot(s, 0, &[7, 7, 7, 7]).unwrap();
            ctx.put(s, 0, peer, s, 4, 4, MSG_DEFAULT).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let mut got = [0u8; 4];
            ctx.read_slot(s, 4, &mut got).unwrap();
            assert_eq!(got, [7, 7, 7, 7]);
        },
        Args::none(),
    )
    .unwrap();
}

#[test]
fn failed_validation_is_side_effect_free_and_capacity_still_mitigable() {
    exec(
        &root(2),
        2,
        |ctx, _| {
            ctx.bootstrap(1, 1).unwrap();
            let s = ctx.register_global(8).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            // illegal bounds do not consume queue capacity…
            assert!(ctx.put(s, 0, 0, s, 4, 16, MSG_DEFAULT).is_err());
            // …so the one-slot queue still accepts the legal request
            ctx.put(s, 0, (ctx.pid() + 1) % 2, s, 4, 4, MSG_DEFAULT).unwrap();
            // and overflowing it stays a mitigable QueueCapacity error
            let err = ctx.put(s, 0, 0, s, 4, 4, MSG_DEFAULT).unwrap_err();
            assert!(err.is_mitigable(), "got {err:?}");
            ctx.sync(SYNC_DEFAULT).unwrap();
        },
        Args::none(),
    )
    .unwrap();
}

// ------------------------------------------------------------- epoch guard

#[test]
fn superstep_value_is_returned_after_the_fence() {
    let outs = exec(
        &root(3),
        3,
        |ctx, _| {
            ctx.bootstrap(2, ctx.p() as usize).unwrap();
            let ring = ctx.alloc_global::<u64>(1).unwrap();
            let next = ctx.alloc_global::<u64>(1).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let mut token = ctx.pid() as u64;
            ctx.write(ring, 0, &[token]).unwrap();
            for _ in 0..ctx.p() {
                let staged = ctx
                    .superstep(|ep| {
                        ep.put_slice(ring, 0, (ep.pid() + 1) % ep.p(), next, 0, 1)?;
                        Ok(ep.p())
                    })
                    .unwrap();
                assert_eq!(staged, ctx.p());
                token = ctx.read_vec(next).unwrap()[0] + 1;
                ctx.write(ring, 0, &[token]).unwrap();
            }
            token
        },
        Args::none(),
    )
    .unwrap();
    // the token returns home having been incremented p times
    assert_eq!(outs, vec![3, 4, 5]);
}

#[test]
fn failed_epoch_propagates_without_fencing() {
    exec(
        &root(2),
        2,
        |ctx, _| {
            ctx.bootstrap(2, 4).unwrap();
            let s = ctx.alloc_global::<u32>(2).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            // the closure errors before staging anything: no fence ran, so
            // both processes are still aligned on superstep count
            let err = ctx
                .superstep(|_| -> lpf::core::Result<()> {
                    Err(LpfError::Illegal("application abort".into()))
                })
                .unwrap_err();
            assert!(matches!(err, LpfError::Illegal(_)));
            // a later complete superstep still works on every process
            ctx.write(s, 0, &[ctx.pid() + 1]).unwrap();
            ctx.superstep(|ep| {
                let peer = (ep.pid() + 1) % 2;
                ep.put_slice(s, 0, peer, s, 1, 1)
            })
            .unwrap();
            let v = ctx.read_vec(s).unwrap();
            assert_eq!(v[1], 2 - ctx.pid());
        },
        Args::none(),
    )
    .unwrap();
}
