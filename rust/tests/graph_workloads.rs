//! Graphs at scale (ISSUE 9): end-to-end workloads pinning the tentpole's
//! guarantees in `cargo test` (throughput gates run in CI via
//! `bench_graph --smoke`):
//!
//! * **rank parity** — the pure-Spark PageRank and the LPF PageRank follow
//!   the same trajectory on a dangling-patched R-MAT graph (the canonical
//!   Spark formulation scales ranks by `n` and has no dangling handling,
//!   so sinks are patched before comparing);
//! * **2D ≡ 1D** — the grid SpMV's sequential pipeline reduce is
//!   bit-identical to the 1-D row-block kernel and the serial oracle on
//!   every backend of the sweep, flat and routed;
//! * **fault adversary** — an injected abort mid-PageRank surfaces as a
//!   clean error, cold-rebuilds the pool once, and the warm retry on the
//!   same pool is bit-identical to a clean-pool run.

use lpf::check::classify;
use lpf::collectives::Coll;
use lpf::core::{Args, Result, SYNC_DEFAULT};
use lpf::ctx::{exec, Platform, Root};
use lpf::graphblas::grid::{partition_grid, spmv_rows_1d, GridSpmv, Scheme};
use lpf::graphblas::{partition, pool_pagerank_runs, Compute};
use lpf::graphgen::{rmat, Coo, RmatConfig};
use lpf::netsim::faults::{FaultPlan, FaultSpec};
use lpf::pool::Pool;
use lpf::sparksim::pagerank::{accelerated_pagerank, pure_spark_pagerank};
use lpf::sparksim::Spark;
use lpf::util::rng::XorShift64;

/// Give every sink one out-edge so the canonical Spark formulation (no
/// dangling handling) and the LPF PageRank share one trajectory.
fn patch_dangling(g: &Coo) -> Coo {
    let mut edges = g.edges.clone();
    for (v, &d) in g.out_degrees().iter().enumerate() {
        if d == 0 {
            edges.push((v as u32, ((v + 1) % g.n) as u32));
        }
    }
    Coo { n: g.n, edges }
}

#[test]
fn spark_and_lpf_pagerank_agree_on_seeded_rmat() {
    let g = patch_dangling(&rmat(&RmatConfig::new(8, 8, 99)));
    assert_eq!(g.dangling_count(), 0);
    let n = g.n;
    let iters = 30u32;
    let sc = Spark::new(4, 8);
    let spark = pure_spark_pagerank(&sc, &g.edges, iters, 10);
    // eps = 0 pins the LPF side to exactly `iters` iterations
    let nnz_pad = (g.edges.len() + n).next_power_of_two();
    let lpf = accelerated_pagerank(
        &sc,
        &g,
        Compute::Native,
        0.85,
        0.0,
        iters,
        nnz_pad,
        "t-parity",
    )
    .unwrap();
    assert_eq!(lpf.iters, iters);
    // every vertex has out-degree ≥ 1 after patching, so the Spark side
    // ranks all n vertices
    assert_eq!(spark.len(), n);
    let mut spark_by_v = vec![0f64; n];
    for (v, r) in spark {
        spark_by_v[v as usize] = r;
    }
    // with zero dangling mass, spark_rank = n · lpf_rank exactly in real
    // arithmetic; tolerance covers f64-vs-f32 roundoff over 30 iterations
    for v in 0..n {
        let want = spark_by_v[v];
        let got = n as f64 * lpf.ranks[v] as f64;
        assert!(
            (want - got).abs() < 2e-3 * want.max(1.0),
            "vertex {v}: spark {want} vs n·lpf {got}"
        );
    }
}

#[test]
fn grid_spmv_bit_consistent_with_1d_across_backends_and_p() {
    let g = rmat(&RmatConfig::new(7, 8, 5));
    let n = g.n;
    let mut rng = XorShift64::new(77);
    let x: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32).collect();
    // serial oracle: the 1-D Native kernel over the whole matrix
    let pad = (g.edges.len() + n).next_power_of_two();
    let serial = Compute::Native.spmv(&partition(&g, 1, pad).unwrap()[0], &x).unwrap();
    for p in [4u32, 9] {
        let q = (p as f64).sqrt() as u32;
        let backends: [(&str, Platform); 3] = [
            ("shared", Platform::shared()),
            ("rdma", Platform::rdma()),
            ("hybrid-fat", Platform::hybrid_fat_tree(q)),
        ];
        let gblocks = partition_grid(&g, q).unwrap();
        let blocks1d = partition(&g, p, pad).unwrap();
        for (name, plat) in backends {
            let root = Root::new(plat.checked(true)).with_max_procs(p);
            let outs = exec(
                &root,
                p,
                |ctx, _| -> Result<(Vec<f32>, Vec<f32>)> {
                    let me = ctx.pid() as usize;
                    let pp = ctx.p() as usize;
                    ctx.bootstrap(16, 8 * pp + 8)?;
                    // grid auto-selection is topology-driven; this sweep
                    // forces Grid{q} on the flat backends as well
                    let scheme = Scheme::Grid { q };
                    assert_eq!(scheme.label(), "grid-2d");
                    let mut sp = GridSpmv::new(ctx, gblocks[me].clone())?;
                    let coll = Coll::new(ctx, 4 * n)?;
                    ctx.sync(SYNC_DEFAULT)?;
                    // 2D path: diagonal (j, j) owns x block j and y block j
                    let qq = q as usize;
                    let diag = me / qq == me % qq;
                    let (x_mine, mut y_grid) = if diag {
                        let blk = &sp.block;
                        (x[blk.col_begin..blk.col_end].to_vec(), vec![0f32; blk.rows_len()])
                    } else {
                        (Vec::new(), Vec::new())
                    };
                    sp.spmv(ctx, &x_mine, &mut y_grid)?;
                    // 1-D path on the same context: row blocks + allgather
                    let rows_per = n.div_ceil(pp);
                    let (lo, hi) = ((me * rows_per).min(n), ((me + 1) * rows_per).min(n));
                    let y_1d = spmv_rows_1d(ctx, &coll, &blocks1d[me], &x[lo..hi])?;
                    sp.free(ctx)?;
                    coll.free(ctx)?;
                    ctx.sync(SYNC_DEFAULT)?;
                    Ok((y_grid, y_1d))
                },
                Args::none(),
            )
            .unwrap();
            let b = n.div_ceil(q as usize);
            let mut y_grid_full = vec![0f32; n];
            let mut y_1d_full = Vec::with_capacity(n);
            for (me, out) in outs.into_iter().enumerate() {
                let (yg, y1) = out.unwrap_or_else(|e| panic!("{name} p={p} pid {me}: {e:?}"));
                let (gi, gj) = (me / q as usize, me % q as usize);
                if gi == gj {
                    y_grid_full[gi * b..gi * b + yg.len()].copy_from_slice(&yg);
                } else {
                    assert!(yg.is_empty());
                }
                y_1d_full.extend(y1);
            }
            y_1d_full.truncate(n);
            for v in 0..n {
                assert_eq!(
                    y_grid_full[v].to_bits(),
                    serial[v].to_bits(),
                    "{name} p={p}: grid y[{v}] = {} vs serial {}",
                    y_grid_full[v],
                    serial[v]
                );
                assert_eq!(
                    y_1d_full[v].to_bits(),
                    serial[v].to_bits(),
                    "{name} p={p}: 1-D y[{v}] = {} vs serial {}",
                    y_1d_full[v],
                    serial[v]
                );
            }
        }
    }
}

#[test]
fn abort_mid_pagerank_is_clean_and_warm_retry_is_bit_identical() {
    let g = rmat(&RmatConfig::new(7, 8, 42));
    let p = 4u32;
    let pad = (g.edges.len() + g.n).next_power_of_two();
    let blocks = partition(&g, p, pad).unwrap();
    let runs = [(1e-6f32, 60u32)];
    // clean reference on a fresh pool
    let clean = pool_pagerank_runs(
        &Pool::new(Platform::shared().checked(true), p),
        &blocks,
        0.85,
        &runs,
    )
    .unwrap();
    // inject an abort mid-iteration (fences 0–1 are setup; step 5 lands
    // inside the warm loop)
    let pool = Pool::new(Platform::shared().checked(true), p);
    let plan = FaultPlan::one(FaultSpec::AbortAtSuperstep { pid: 1, step: 5 });
    pool.set_fault_plan(Some(plan.clone()));
    let err = pool_pagerank_runs(&pool, &blocks, 0.85, &runs).unwrap_err();
    // pid 0 observes its peer's abort; the injected error lives on pid 1 —
    // either way the failure is a clean, classified LpfError
    let class = classify(&err);
    assert!(
        class == "peer-aborted" || class == "injected",
        "unexpected class {class}: {err:?}"
    );
    assert_eq!(plan.injections(), 1, "the abort must have fired exactly once");
    assert!(pool.stats().cold_resets >= 1, "failed job must cold-rebuild the team");
    // warm retry on the same pool: the one-shot fault stays exhausted and
    // the result is bit-identical to the clean-pool run
    let retry = pool_pagerank_runs(&pool, &blocks, 0.85, &runs).unwrap();
    assert_eq!(retry.len(), 1);
    assert_eq!(retry[0].iters, clean[0].iters);
    assert_eq!(retry[0].ranks, clean[0].ranks, "warm retry must be bit-identical");
    assert_eq!(plan.injections(), 1, "one-shot fault must not re-fire");
}
