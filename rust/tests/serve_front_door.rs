//! Front-door suite (ISSUE 6): the serving layer over the hot team.
//!
//! Pins the tentpole's contracts in `cargo test` (throughput and the
//! zero-allocation gate run in CI via `bench_serve --smoke`):
//!
//! * per-class FIFO and exactly-once completion hold while many threads
//!   submit through the front door interleaved with direct `Pool::submit`
//!   / `Pool::exec` jobs on the same team;
//! * admission control rejects with a clean `Overloaded` error and the
//!   door recovers once the backlog drains;
//! * an injected abort inside a batched job fails exactly that batch's
//!   requests with a clean error class, costs one cold rebuild, and the
//!   replicated KV store survives into the next batch — on shared and
//!   rdma fabrics, cold and warm.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use lpf::check::classify;
use lpf::core::{Args, Pid, Result};
use lpf::ctx::{Context, Platform};
use lpf::netsim::faults::{FaultPlan, FaultSpec};
use lpf::serve::kv::{KvOp, KvStatus, KvTenant, KV_VAL};
use lpf::serve::{BatchView, ClassConfig, QueueClass, Serve, ServeConfig, ServeError, Tenant};

fn val(seed: u8) -> [u8; KV_VAL] {
    let mut v = [0u8; KV_VAL];
    for (i, b) in v.iter_mut().enumerate() {
        *b = seed.wrapping_add(i as u8);
    }
    v
}

// ----------------------------------------------------------- fifo tenant

/// Records, on pid 0, every request in dispatch order and echoes it back
/// transformed. No supersteps — a pure dispatch-order probe.
struct EchoTenant {
    log: Arc<Mutex<Vec<u64>>>,
}

const ECHO_XOR: u64 = 0x5A5A_0000_0000_5A5A;

impl Tenant for EchoTenant {
    type Req = u64;
    type Resp = u64;

    fn run_batch(&self, ctx: &mut Context, batch: &mut BatchView<'_, u64, u64>) -> Result<()> {
        if ctx.pid() == 0 {
            let mut log = self.log.lock().expect("log poisoned");
            for i in 0..batch.len() {
                let r = *batch.req(i);
                log.push(r);
                batch.put_resp(i, r ^ ECHO_XOR);
            }
        }
        Ok(())
    }
}

#[test]
fn concurrent_submitters_and_direct_pool_jobs_keep_fifo_and_exactly_once() {
    const SUBMITTERS: u64 = 6;
    const PER_SUBMITTER: u64 = 64;
    const DIRECT_JOBS: u64 = 24;

    let log = Arc::new(Mutex::new(Vec::new()));
    let serve = Serve::new(
        Platform::shared().checked(true),
        2,
        EchoTenant { log: Arc::clone(&log) },
        ServeConfig::default(),
    );
    let direct_sum = AtomicU64::new(0);

    std::thread::scope(|scope| {
        for s in 0..SUBMITTERS {
            let serve = &serve;
            scope.spawn(move || {
                let class = QueueClass::ALL[(s % 3) as usize];
                // pipeline the submissions so queue order is actually
                // exercised, then wait them all
                let pending: Vec<_> = (0..PER_SUBMITTER)
                    .map(|q| serve.submit(class, (s << 32) | q).expect("within capacity"))
                    .collect();
                for (q, pend) in pending.into_iter().enumerate() {
                    let resp = pend.wait().expect("batch must complete");
                    assert_eq!(
                        resp,
                        ((s << 32) | q as u64) ^ ECHO_XOR,
                        "response delivered to the wrong ticket"
                    );
                }
            });
        }
        // direct jobs race the dispatcher through the pool's own FIFO
        let direct_sum = &direct_sum;
        let serve = &serve;
        scope.spawn(move || {
            for j in 0..DIRECT_JOBS {
                if j % 2 == 0 {
                    let outs = serve
                        .pool()
                        .exec(move |ctx: &mut Context, _| ctx.pid() as u64 + j, Args::none())
                        .expect("direct exec");
                    direct_sum.fetch_add(outs.iter().sum::<u64>(), Ordering::Relaxed);
                } else {
                    let h = serve
                        .pool()
                        .submit(move |ctx: &mut Context, _| ctx.pid() as u64 + j, Args::none());
                    let outs = h.wait().expect("direct submit");
                    direct_sum.fetch_add(outs.iter().sum::<u64>(), Ordering::Relaxed);
                }
            }
        });
    });

    // direct jobs computed correctly despite interleaving
    let want: u64 = (0..DIRECT_JOBS).map(|j| 2 * j + 1).sum();
    assert_eq!(direct_sum.load(Ordering::Relaxed), want);

    // exactly-once + per-submitter FIFO: walking the dispatch log, every
    // submitter's sequence numbers appear 0,1,2,... with no gap, no
    // repeat, no loss
    let log = log.lock().expect("log poisoned");
    assert_eq!(log.len() as u64, SUBMITTERS * PER_SUBMITTER, "lost or duplicated requests");
    let mut next = [0u64; SUBMITTERS as usize];
    for r in log.iter() {
        let (s, q) = ((r >> 32) as usize, r & 0xFFFF_FFFF);
        assert_eq!(q, next[s], "submitter {s}: out-of-order dispatch");
        next[s] += 1;
    }
    assert!(next.iter().all(|&n| n == PER_SUBMITTER));

    let stats = serve.stats();
    let completed: u64 = QueueClass::ALL.iter().map(|c| stats.class(*c).completed).sum();
    assert_eq!(completed, SUBMITTERS * PER_SUBMITTER);
    assert_eq!(QueueClass::ALL.iter().map(|c| stats.class(*c).failed).sum::<u64>(), 0);
    // every pool job was either a batch or a direct job — none invented,
    // none lost
    assert_eq!(stats.pool.jobs_completed, stats.batches_dispatched + DIRECT_JOBS);
}

// -------------------------------------------------------------- overload

struct SlowTenant;

impl Tenant for SlowTenant {
    type Req = ();
    type Resp = ();

    fn run_batch(&self, _ctx: &mut Context, _batch: &mut BatchView<'_, (), ()>) -> Result<()> {
        std::thread::sleep(Duration::from_millis(4));
        Ok(())
    }
}

#[test]
fn admission_control_rejects_when_full_and_recovers() {
    let capacity = 2;
    let config = ServeConfig {
        interactive: ClassConfig {
            capacity,
            max_batch: 1,
            max_linger: Duration::ZERO,
        },
        ..ServeConfig::default()
    };
    let serve = Serve::new(Platform::shared().checked(true), 2, SlowTenant, config);

    // burst far past capacity: with 4ms service per 1-request batch, the
    // tight loop must hit a full queue
    let mut accepted = Vec::new();
    let mut rejections = 0u64;
    for _ in 0..24 {
        match serve.submit(QueueClass::Interactive, ()) {
            Ok(p) => accepted.push(p),
            Err(e) => {
                assert_eq!(
                    e,
                    ServeError::Overloaded { class: QueueClass::Interactive, capacity },
                    "rejection must carry the class and its bound"
                );
                assert!(e.is_overloaded());
                rejections += 1;
            }
        }
    }
    assert!(rejections > 0, "burst of 24 into a 2-deep queue must overflow");
    // backpressure is explicit, not destructive: everything admitted
    // completes
    for p in accepted {
        p.wait().expect("admitted requests must complete");
    }
    // and the door recovers once the backlog drained
    serve.submit_wait(QueueClass::Interactive, ()).expect("must recover after drain");

    let stats = serve.stats();
    assert_eq!(stats.class(QueueClass::Interactive).rejected, rejections);
    assert!(stats.class(QueueClass::Interactive).queue_wait.count > 0);
}

// -------------------------------------------------- fault adversary (kv)

/// An injected abort inside a batched KV job: exactly that batch fails,
/// with a clean error class; one cold rebuild; the host-resident replicas
/// survive and serve the next batch.
#[test]
fn injected_abort_fails_only_its_batch_and_replicas_survive() {
    for warm in [false, true] {
        for backend in ["shared", "rdma"] {
            let platform = match backend {
                "shared" => Platform::shared().checked(true),
                _ => Platform::rdma().checked(true),
            };
            let p: Pid = 2;
            let serve = Serve::new(platform, p, KvTenant::new(p, 128, 8), ServeConfig::default());
            let mode = if warm { "warm" } else { "cold" };
            let tag = format!("{backend}/{mode}");

            if warm {
                for k in 0..8u64 {
                    let r = serve
                        .submit_wait(QueueClass::Interactive, KvOp::put(k, val(k as u8)))
                        .unwrap_or_else(|e| panic!("{tag}: warm-up put {k}: {e}"));
                    assert_eq!(r.status, KvStatus::Ok, "{tag}");
                }
            }

            let plan = FaultPlan::one(FaultSpec::AbortAtSuperstep { pid: 1, step: 2 });
            serve.pool().set_fault_plan(Some(plan.clone()));
            let resets_before = serve.pool().stats().cold_resets;

            let err = serve
                .submit_wait(QueueClass::Interactive, KvOp::get(0))
                .expect_err(&format!("{tag}: the doomed batch must fail"));
            match &err {
                ServeError::Job(e) => {
                    let class = classify(e);
                    assert!(
                        class == "peer-aborted" || class == "fatal",
                        "{tag}: unclean error class {class}: {e:?}"
                    );
                }
                other => panic!("{tag}: expected ServeError::Job, got {other:?}"),
            }
            assert_eq!(plan.injections(), 1, "{tag}: fault must fire exactly once");
            assert_eq!(
                serve.pool().stats().cold_resets,
                resets_before + 1,
                "{tag}: a failed batch costs exactly one cold rebuild"
            );

            // recovery on the rebuilt team; replicas survive the rebuild
            if warm {
                for k in 0..8u64 {
                    let r = serve
                        .submit_wait(QueueClass::Interactive, KvOp::get(k))
                        .unwrap_or_else(|e| panic!("{tag}: post-abort get {k}: {e}"));
                    assert_eq!(r.status, KvStatus::Ok, "{tag}: key {k} lost in rebuild");
                    assert_eq!(r.val, val(k as u8), "{tag}: key {k} corrupted");
                }
            } else {
                let r = serve
                    .submit_wait(QueueClass::Interactive, KvOp::put(7, val(7)))
                    .unwrap_or_else(|e| panic!("{tag}: post-abort put: {e}"));
                assert_eq!(r.status, KvStatus::Ok, "{tag}");
                let r = serve
                    .submit_wait(QueueClass::Interactive, KvOp::get(7))
                    .unwrap_or_else(|e| panic!("{tag}: post-abort get: {e}"));
                assert_eq!((r.status, r.val), (KvStatus::Ok, val(7)), "{tag}");
            }

            let stats = serve.stats();
            let c = stats.class(QueueClass::Interactive);
            assert_eq!(c.failed, 1, "{tag}: exactly the doomed batch's request fails");
            assert_eq!(
                c.completed + c.failed,
                c.submitted,
                "{tag}: every admitted request settled exactly once"
            );
        }
    }
}
