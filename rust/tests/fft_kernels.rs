//! Property tests for the rebuilt FFT kernel suite: the cache-blocked
//! radix-4 native kernel vs the naive-DFT oracle and the retained radix-2
//! baseline, the fused post-twiddle epilogue, and the strided/batched
//! kernels across shapes (ISSUE-5 test-coverage satellite).

use lpf::fft::baseline;
use lpf::fft::local;
use lpf::fft::plan::FftPlan;
use lpf::util::rng::XorShift64;

fn rand_planes(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = XorShift64::new(seed);
    let re: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
    let im: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
    (re, im)
}

/// Max |a - b| over both planes.
fn max_err(ar: &[f32], ai: &[f32], br: &[f32], bi: &[f32]) -> f32 {
    ar.iter()
        .zip(br)
        .chain(ai.iter().zip(bi))
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max)
}

/// Rounding tolerance for size n (errors grow ~sqrt(log n) per plane, the
/// input is O(1) per element so spectra are O(sqrt n)).
fn tol(n: usize) -> f32 {
    1e-5 * (n as f32).sqrt().max(1.0) * (n as f32).log2().max(1.0)
}

#[test]
fn radix4_matches_naive_dft_small() {
    for bits in 1..=10u32 {
        let n = 1usize << bits;
        let plan = FftPlan::new(n).unwrap();
        let (re, im) = rand_planes(n, 100 + bits as u64);
        let (fr, fi) = local::fft(&plan, &re, &im).unwrap();
        let (dr, di) = local::dft_naive(&re, &im);
        assert!(
            max_err(&fr, &fi, &dr, &di) < 1e-2 * (n as f32).sqrt(),
            "radix-4 vs naive DFT diverged at n={n}"
        );
    }
}

#[test]
fn radix4_matches_radix2_baseline_up_to_2p16() {
    // covers both log2 parities and both the single-block and the
    // blocked (n > 2^13) code paths
    for bits in [1u32, 2, 3, 5, 8, 11, 12, 13, 14, 15, 16] {
        let n = 1usize << bits;
        let plan = FftPlan::new(n).unwrap();
        let (re, im) = rand_planes(n, 7 + bits as u64);
        let (fr, fi) = local::fft(&plan, &re, &im).unwrap();
        let (br, bi) = baseline::fft_radix2(&plan, &re, &im).unwrap();
        assert!(
            max_err(&fr, &fi, &br, &bi) < tol(n),
            "radix-4 vs radix-2 diverged at n={n} (err {})",
            max_err(&fr, &fi, &br, &bi)
        );
    }
}

#[test]
fn fused_post_twiddle_equals_fft_then_mul() {
    // 14 and 15 exceed one cache block (2^12 even / 2^13 odd), so the
    // post-multiply runs in the streaming top-stage path there — the
    // large-m production regime — not the blocked bottom loop
    for bits in [1u32, 2, 4, 7, 10, 13, 14, 15] {
        let n = 1usize << bits;
        let plan = FftPlan::new(n).unwrap();
        let (re, im) = rand_planes(n, 21 + bits as u64);
        // a unit-magnitude twiddle table (the BSP use) plus a generic one
        for (tw_seed, unit) in [(1u64, true), (2u64, false)] {
            let mut rng = XorShift64::new(tw_seed);
            let mut tw_re = vec![0f32; n];
            let mut tw_im = vec![0f32; n];
            for k in 0..n {
                if unit {
                    let ang = 2.0 * std::f64::consts::PI * rng.unit_f64();
                    tw_re[k] = ang.cos() as f32;
                    tw_im[k] = ang.sin() as f32;
                } else {
                    tw_re[k] = rng.unit_f64() as f32 - 0.5;
                    tw_im[k] = rng.unit_f64() as f32 - 0.5;
                }
            }
            let mut fr = re.clone();
            let mut fi = im.clone();
            local::fft_in_place_post_mul(&plan, &mut fr, &mut fi, &tw_re, &tw_im).unwrap();
            let (xr, xi) = local::fft(&plan, &re, &im).unwrap();
            let want_re: Vec<f32> = (0..n).map(|k| xr[k] * tw_re[k] - xi[k] * tw_im[k]).collect();
            let want_im: Vec<f32> = (0..n).map(|k| xr[k] * tw_im[k] + xi[k] * tw_re[k]).collect();
            assert!(
                max_err(&fr, &fi, &want_re, &want_im) < tol(n),
                "fused post-twiddle diverged at n={n}"
            );
        }
    }
}

/// Gather transform `t` out of the strided layout.
fn gather(buf: &[f32], n: usize, stride: usize, t: usize) -> Vec<f32> {
    (0..n).map(|j| buf[j * stride + t]).collect()
}

#[test]
fn batch_strided_matches_per_row_ffts() {
    let shapes =
        [(2usize, 3usize, 5usize), (4, 4, 4), (8, 16, 16), (16, 7, 9), (64, 32, 32)];
    for &(n, count, stride) in &shapes {
        let plan = FftPlan::new(n).unwrap();
        let len = (n - 1) * stride + count;
        let (re0, im0) = rand_planes(len, (n * 31 + count) as u64);
        let mut re = re0.clone();
        let mut im = im0.clone();
        local::fft_batch_strided(&plan, &mut re, &mut im, count, stride).unwrap();
        for t in 0..count {
            let (wr, wi) =
                local::fft(&plan, &gather(&re0, n, stride, t), &gather(&im0, n, stride, t))
                    .unwrap();
            let gr = gather(&re, n, stride, t);
            let gi = gather(&im, n, stride, t);
            assert!(
                max_err(&gr, &gi, &wr, &wi) < tol(n),
                "batch strided diverged at n={n} count={count} stride={stride} t={t}"
            );
        }
        // the kernel may only touch columns t < count of each row;
        // the tail columns must come through bit-identical
        for j in 0..n {
            for t in count..stride.min(len - j * stride) {
                let idx = j * stride + t;
                assert_eq!(re[idx], re0[idx], "re column {t} of row {j} was clobbered");
                assert_eq!(im[idx], im0[idx], "im column {t} of row {j} was clobbered");
            }
        }
    }
}

#[test]
fn batch_strided_out_is_the_transposed_batch() {
    let shapes =
        [(2usize, 3usize, 5usize), (4, 4, 4), (8, 16, 16), (16, 7, 9), (64, 32, 32)];
    for &(n, count, stride) in &shapes {
        let plan = FftPlan::new(n).unwrap();
        let len = (n - 1) * stride + count;
        let (re0, im0) = rand_planes(len, (n * 17 + count) as u64);
        let mut out_re = vec![0f32; count * n];
        let mut out_im = vec![0f32; count * n];
        let mut re = re0.clone();
        let mut im = im0.clone();
        let (o_r, o_i) = (&mut out_re, &mut out_im);
        local::fft_batch_strided_out(&plan, &mut re, &mut im, count, stride, o_r, o_i)
            .unwrap();
        for t in 0..count {
            let (wr, wi) =
                local::fft(&plan, &gather(&re0, n, stride, t), &gather(&im0, n, stride, t))
                    .unwrap();
            let gr = &out_re[t * n..(t + 1) * n];
            let gi = &out_im[t * n..(t + 1) * n];
            assert!(
                max_err(gr, gi, &wr, &wi) < tol(n),
                "batch strided out diverged at n={n} count={count} stride={stride} t={t}"
            );
        }
    }
}

#[test]
fn batch_strided_count_zero_is_a_noop() {
    let plan = FftPlan::new(8).unwrap();
    let mut re = vec![1f32; 32];
    let mut im = vec![2f32; 32];
    local::fft_batch_strided(&plan, &mut re, &mut im, 0, 4).unwrap();
    assert!(re.iter().all(|&x| x == 1.0) && im.iter().all(|&x| x == 2.0));
}

#[test]
fn batch_strided_rejects_bad_shapes_without_panicking() {
    let plan = FftPlan::new(8).unwrap();
    let mut re = vec![0f32; 64];
    let mut im = vec![0f32; 64];
    // count > stride
    assert!(local::fft_batch_strided(&plan, &mut re, &mut im, 9, 8).is_err());
    // planes too short for the strided extent
    assert!(local::fft_batch_strided(&plan, &mut re, &mut im, 8, 16).is_err());
    // output too short
    let mut o1 = vec![0f32; 8];
    let mut o2 = vec![0f32; 8];
    assert!(
        local::fft_batch_strided_out(&plan, &mut re, &mut im, 8, 8, &mut o1, &mut o2).is_err()
    );
}

/// Regression (ISSUE-5 satellite 1): the pre-rebuild kernel used
/// `assert_eq!` on the input lengths despite returning `Result` — every
/// kernel must report `Illegal` instead of panicking.
#[test]
fn all_kernels_reject_length_mismatch_as_illegal() {
    let plan = FftPlan::new(16).unwrap();
    let mut short = vec![0f32; 8];
    let mut ok = vec![0f32; 16];
    assert!(local::fft_in_place(&plan, &mut short, &mut ok).is_err());
    assert!(local::fft_in_place(&plan, &mut ok, &mut short).is_err());
    assert!(baseline::fft_radix2_in_place(&plan, &mut short, &mut ok).is_err());
    let tw = vec![0f32; 8];
    assert!(local::fft_in_place_post_mul(&plan, &mut ok, &mut ok.clone(), &tw, &tw).is_err());
}

/// The SIMD satellite: every lane width must reproduce the scalar
/// kernel **bit for bit** (same per-element expression tree, no
/// reassociation), across both radix parities, the cache-block boundary
/// (2^12 even / 2^13 odd), and the fused-twiddle epilogue. The scalar
/// kernel stays the correctness oracle against the naive DFT (small n)
/// and the radix-2 baseline (large n).
#[test]
fn lane_sweeps_match_scalar_bitwise_from_2_to_2p16() {
    use lpf::simd::Lane;
    for bits in [1u32, 2, 3, 4, 5, 8, 11, 12, 13, 14, 16] {
        let n = 1usize << bits;
        let plan = FftPlan::new(n).unwrap();
        let (re, im) = rand_planes(n, 400 + bits as u64);
        let run = |lane| {
            let mut r = re.clone();
            let mut i = im.clone();
            local::fft_in_place_with_lane(&plan, &mut r, &mut i, lane).unwrap();
            (r, i)
        };
        let (sr, si) = run(Lane::Scalar);
        for lane in [Lane::X4, Lane::X8] {
            let (lr, li) = run(lane);
            for k in 0..n {
                assert_eq!(sr[k].to_bits(), lr[k].to_bits(), "{lane:?} re[{k}] n={n}");
                assert_eq!(si[k].to_bits(), li[k].to_bits(), "{lane:?} im[{k}] n={n}");
            }
        }
        // the scalar oracle itself is checked against an independent
        // implementation: naive DFT while O(n²) is affordable, the
        // retained radix-2 baseline beyond
        if bits <= 10 {
            let (dr, di) = local::dft_naive(&re, &im);
            assert!(max_err(&sr, &si, &dr, &di) < 1e-2 * (n as f32).sqrt(), "oracle n={n}");
        } else {
            let (br, bi) = baseline::fft_radix2(&plan, &re, &im).unwrap();
            assert!(max_err(&sr, &si, &br, &bi) < tol(n), "oracle n={n}");
        }
    }
}

/// Lane/scalar bit-identity for the fused post-twiddle epilogue and the
/// batched/strided kernels, including counts that are not a multiple of
/// any lane width (scalar-tail coverage) and the transposed-output form.
#[test]
fn lane_fused_and_batched_kernels_match_scalar_bitwise() {
    use lpf::simd::Lane;
    for bits in [2u32, 5, 10, 13, 14] {
        let n = 1usize << bits;
        let plan = FftPlan::new(n).unwrap();
        let (re, im) = rand_planes(n, 500 + bits as u64);
        let mut rng = XorShift64::new(9 + bits as u64);
        let tw_re: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
        let tw_im: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
        let fused = |lane| {
            let mut r = re.clone();
            let mut i = im.clone();
            local::fft_in_place_post_mul_with_lane(&plan, &mut r, &mut i, &tw_re, &tw_im, lane)
                .unwrap();
            (r, i)
        };
        let (sr, si) = fused(Lane::Scalar);
        for lane in [Lane::X4, Lane::X8] {
            let (lr, li) = fused(lane);
            assert!(
                sr.iter().zip(&lr).all(|(a, b)| a.to_bits() == b.to_bits())
                    && si.iter().zip(&li).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused {lane:?} diverged at n={n}"
            );
        }
    }
    // batched shapes: counts 1..17 cross every tail residue of both widths
    for &(n, count, stride) in
        &[(8usize, 1usize, 3usize), (8, 3, 3), (16, 5, 6), (16, 7, 7), (32, 9, 12), (64, 17, 17)]
    {
        let plan = FftPlan::new(n).unwrap();
        let len = (n - 1) * stride + count;
        let (re0, im0) = rand_planes(len, (n * 13 + count) as u64);
        let in_place = |lane| {
            let mut r = re0.clone();
            let mut i = im0.clone();
            local::fft_batch_strided_with_lane(&plan, &mut r, &mut i, count, stride, lane)
                .unwrap();
            (r, i)
        };
        let transposed = |lane| {
            let mut r = re0.clone();
            let mut i = im0.clone();
            let mut or = vec![0f32; count * n];
            let mut oi = vec![0f32; count * n];
            local::fft_batch_strided_out_with_lane(
                &plan, &mut r, &mut i, count, stride, &mut or, &mut oi, lane,
            )
            .unwrap();
            (or, oi)
        };
        let scalar_ip = in_place(Lane::Scalar);
        let scalar_tr = transposed(Lane::Scalar);
        for lane in [Lane::X4, Lane::X8] {
            for (scalar, got, kind) in
                [(&scalar_ip, in_place(lane), "in-place"), (&scalar_tr, transposed(lane), "out")]
            {
                assert!(
                    scalar.0.iter().zip(&got.0).all(|(a, b)| a.to_bits() == b.to_bits())
                        && scalar.1.iter().zip(&got.1).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "batch {kind} {lane:?} diverged at n={n} count={count} stride={stride}"
                );
            }
        }
    }
}

#[test]
fn plan_cache_is_shared_and_kernels_agree_through_it() {
    let a = FftPlan::cached(256).unwrap();
    let b = FftPlan::cached(256).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    let (re, im) = rand_planes(256, 5);
    let (fr, fi) = local::fft(&a, &re, &im).unwrap();
    let (br, bi) = baseline::fft_radix2(&b, &re, &im).unwrap();
    assert!(max_err(&fr, &fi, &br, &bi) < tol(256));
}

/// The widened permutation (ISSUE-5 satellite 4): `perm` is `u32` end to
/// end; the i32 layout survives only through `perm_i32` for the
/// artifact-tensor boundary, which must refuse (not wrap) oversized n.
#[test]
fn perm_is_u32_with_i32_only_at_the_artifact_boundary() {
    let plan = FftPlan::new(1 << 16).unwrap();
    let max = *plan.perm.iter().max().unwrap();
    assert_eq!(max as usize, (1 << 16) - 1);
    let as_i32 = plan.perm_i32().unwrap();
    assert_eq!(as_i32.len(), 1 << 16);
    assert!(as_i32.iter().all(|&v| v >= 0));
    // the type itself is the regression guard: a Vec<i32> permutation
    // cannot represent indices past 2^31
    let _typed: &Vec<u32> = &plan.perm;
}
