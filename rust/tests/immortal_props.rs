//! Property tests for the immortal suite on warm pools (ISSUE 9): typed
//! `pool_sample_sort` / `pool_list_rank` wrappers exercised over seeded
//! random shapes — empty slices, skewed loads, duplicate-heavy keys —
//! against serial oracles, with every round sharing one persistent pool
//! so warm reuse (no cold rebuilds) is itself part of the property.

use lpf::ctx::Platform;
use lpf::immortal::list_rank::NIL;
use lpf::immortal::sort::verify_sorted;
use lpf::immortal::{pool_list_rank, pool_sample_sort};
use lpf::pool::Pool;
use lpf::util::rng::XorShift64;

#[test]
fn pool_sample_sort_random_shapes_property() {
    let p = 4u32;
    let pool = Pool::new(Platform::shared().checked(true), p);
    let mut rng = XorShift64::new(0x50D7_50D7);
    for round in 0..6usize {
        // random per-pid lengths; every round forces one empty slice and
        // one skewed slice carrying most of the data with heavy duplicates
        let mut inputs: Vec<Vec<u64>> = (0..p)
            .map(|_| {
                let len = rng.below_usize(200);
                (0..len).map(|_| rng.below(1 << 20)).collect()
            })
            .collect();
        inputs[round % p as usize].clear();
        inputs[(round + 1) % p as usize] = (0..2_000).map(|_| rng.below(64)).collect();
        let all: Vec<u64> = inputs.iter().flatten().copied().collect();
        let parts = pool_sample_sort(&pool, &inputs).unwrap();
        assert_eq!(parts.len(), p as usize);
        verify_sorted(&parts, &all).unwrap_or_else(|e| panic!("round {round}: {e:?}"));
    }
    assert_eq!(pool.stats().cold_resets, 0, "warm service must never rebuild");
}

#[test]
fn pool_sample_sort_handles_all_empty_input() {
    let pool = Pool::new(Platform::shared().checked(true), 3);
    let empties: Vec<Vec<u64>> = vec![Vec::new(); 3];
    let parts = pool_sample_sort(&pool, &empties).unwrap();
    assert_eq!(parts.len(), 3);
    assert!(parts.iter().all(|s| s.is_empty()));
}

#[test]
fn pool_sample_sort_rejects_wrong_slice_count() {
    let pool = Pool::new(Platform::shared().checked(true), 3);
    let two: Vec<Vec<u64>> = vec![Vec::new(); 2];
    assert!(pool_sample_sort(&pool, &two).is_err());
}

/// Serial oracle: a random chain over `n` nodes. Returns `(succ, rank)`
/// where `rank[v]` is v's distance to the tail.
fn random_chain(n: usize, rng: &mut XorShift64) -> (Vec<u64>, Vec<u64>) {
    let mut order: Vec<u64> = (0..n as u64).collect();
    rng.shuffle(&mut order);
    let mut succ = vec![NIL; n];
    let mut rank = vec![0u64; n];
    for i in 0..n {
        rank[order[i] as usize] = (n - 1 - i) as u64;
        if i + 1 < n {
            succ[order[i] as usize] = order[i + 1];
        }
    }
    (succ, rank)
}

#[test]
fn pool_list_rank_matches_oracle_across_sizes() {
    // n spans: empty, single node, n < p, n ≁ p, power of two
    let pool = Pool::new(Platform::shared().checked(true), 4);
    let mut rng = XorShift64::new(0x11C4);
    for n in [0usize, 1, 5, 37, 256] {
        let (succ, want) = random_chain(n, &mut rng);
        let got = pool_list_rank(&pool, &succ).unwrap();
        assert_eq!(got, want, "n = {n}");
    }
    assert_eq!(pool.stats().cold_resets, 0, "warm service must never rebuild");
}

#[test]
fn pool_list_rank_repeated_queries_are_deterministic() {
    let pool = Pool::new(Platform::shared().checked(true), 3);
    let mut rng = XorShift64::new(7);
    let (succ, want) = random_chain(100, &mut rng);
    let first = pool_list_rank(&pool, &succ).unwrap();
    assert_eq!(first, want);
    for _ in 0..3 {
        assert_eq!(pool_list_rank(&pool, &succ).unwrap(), first);
    }
}
