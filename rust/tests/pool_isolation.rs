//! Cross-job isolation of the hot-team executor.
//!
//! The pool's contract: a job served by a warm team observes a context
//! **bit-identical in behaviour** to a fresh `exec` — no leaked slots, no
//! inherited queue capacity, no inherited `SyncStats`, simulated clocks at
//! zero — and slot handles never survive the job boundary (a handle from
//! job A used in job B fails with `Illegal`, it can never alias job B's
//! memory). These tests drive a parameterised observer program under both
//! executors and compare every observable, property-test style, over a
//! grid of seeds and process counts.

use std::sync::{Arc, Mutex};

use lpf::core::{Args, LpfError, Memslot, Pid, MSG_DEFAULT, SYNC_DEFAULT};
use lpf::ctx::{exec, Context, Platform, Root};
use lpf::fabric::SyncStats;
use lpf::pool::Pool;

/// Everything a program can observe about the freshness of its context.
#[derive(Debug, Clone, PartialEq)]
struct Observation {
    p: Pid,
    /// Registration before any resize must fail (default capacity 0).
    register_rejected_cold: bool,
    /// Stats at entry must be zeroed.
    stats_at_entry: SyncStats,
    /// Deterministic slot indices (fresh registers start at index 0).
    slot_indices: Vec<u32>,
    /// Allgathered payload (communication works and is correct).
    gathered: Vec<u32>,
    /// Stats after the program's two supersteps.
    stats_after: SyncStats,
    /// Simulated time (netsim backends; None on shared).
    sim_time_ns: Option<f64>,
}

/// The observer program: checks pristine state, then runs a seed-dependent
/// allgather through seed-dependent slot shapes.
fn observe(ctx: &mut Context, seed: u32) -> Observation {
    let p = ctx.p();
    let s = ctx.pid();

    let register_rejected_cold = ctx.register_global(4).is_err();
    let stats_at_entry = ctx.stats();

    let extra = (seed % 3) as usize; // shape varies with the seed
    ctx.resize_memory_register(2 + extra).unwrap();
    ctx.resize_message_queue(p as usize + extra).unwrap();

    // capacity takes effect only at the fence — also true of a fresh ctx
    let mine_probe = ctx.register_global(4);
    assert!(mine_probe.is_err(), "capacity must not pre-activate");
    ctx.sync(SYNC_DEFAULT).unwrap();

    let mut slot_indices = Vec::new();
    let mine = ctx.register_global(4).unwrap();
    slot_indices.push(mine.index());
    let all = ctx.register_global(4 * p as usize).unwrap();
    slot_indices.push(all.index());
    for _ in 0..extra {
        let t = ctx.register_local(8).unwrap();
        slot_indices.push(t.index());
    }

    ctx.write_typed(mine, 0, &[seed.wrapping_mul(31).wrapping_add(s)]).unwrap();
    for k in 0..p {
        ctx.put(mine, 0, k, all, 4 * s as usize, 4, MSG_DEFAULT).unwrap();
    }
    ctx.sync(SYNC_DEFAULT).unwrap();
    let mut gathered = vec![0u32; p as usize];
    ctx.read_typed(all, 0, &mut gathered).unwrap();

    Observation {
        p,
        register_rejected_cold,
        stats_at_entry,
        slot_indices,
        gathered,
        stats_after: ctx.stats(),
        sim_time_ns: ctx.sim_time_ns(),
    }
}

/// A deliberately messy job: raises capacities high, registers and leaks
/// slots, syncs a few times — everything the next job must not see.
fn dirty_job(ctx: &mut Context, seed: u32) -> Memslot {
    let p = ctx.p();
    ctx.resize_memory_register(16 + (seed % 5) as usize).unwrap();
    ctx.resize_message_queue(64).unwrap();
    ctx.sync(SYNC_DEFAULT).unwrap();
    let mut last = None;
    for _ in 0..(3 + seed % 4) {
        last = Some(ctx.register_global(32).unwrap());
    }
    let leak = last.unwrap();
    for k in 0..p {
        ctx.put(leak, 0, k, leak, 4, 4, MSG_DEFAULT).unwrap();
    }
    ctx.sync(SYNC_DEFAULT).unwrap();
    ctx.sync(SYNC_DEFAULT).unwrap();
    leak // leaked on purpose: never deregistered
}

fn fresh_observation(platform: &Platform, p: Pid, seed: u32) -> Vec<Observation> {
    let root = Root::new(platform.clone()).with_max_procs(p);
    exec(&root, p, move |ctx, _| observe(ctx, seed), Args::none()).unwrap()
}

#[test]
fn second_pool_job_is_behaviourally_identical_to_fresh_exec() {
    for platform in [Platform::shared().checked(true), Platform::rdma()] {
        for p in [2 as Pid, 4] {
            let pool = Pool::new(platform.clone(), p);
            for seed in 0..6u32 {
                // job A dirties the team...
                pool.exec(move |ctx, _| dirty_job(ctx, seed), Args::none()).unwrap();
                // ...job B must still observe a fresh context
                let warm = pool
                    .exec(move |ctx, _| observe(ctx, seed), Args::none())
                    .unwrap();
                let fresh = fresh_observation(&platform, p, seed);
                assert_eq!(
                    warm, fresh,
                    "platform {platform:?}, p {p}, seed {seed}: warm job diverged"
                );
            }
        }
    }
}

#[test]
fn queue_capacity_is_cold_after_a_job_that_raised_it() {
    let pool = Pool::new(Platform::shared().checked(true), 2);
    pool.exec(
        |ctx, _| {
            ctx.resize_message_queue(128).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
        },
        Args::none(),
    )
    .unwrap();
    pool.exec(
        |ctx, _| {
            ctx.resize_memory_register(1).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let s = ctx.register_global(8).unwrap();
            // queue capacity is back at the default of zero
            let err = ctx.put(s, 0, 0, s, 4, 4, MSG_DEFAULT).unwrap_err();
            assert_eq!(err, LpfError::QueueCapacity { capacity: 0 });
        },
        Args::none(),
    )
    .unwrap();
}

#[test]
fn slot_handle_from_job_a_is_illegal_in_job_b() {
    let pool = Pool::new(Platform::shared().checked(true), 2);
    let leaked: Arc<Mutex<Vec<Memslot>>> = Arc::new(Mutex::new(Vec::new()));
    {
        let leaked = leaked.clone();
        pool.exec(
            move |ctx, _| {
                let slot = dirty_job(ctx, 1);
                if ctx.pid() == 0 {
                    leaked.lock().unwrap().push(slot);
                }
            },
            Args::none(),
        )
        .unwrap();
    }
    let stale = leaked.lock().unwrap()[0];
    pool.exec(
        move |ctx, _| {
            // resolve paths must reject the stale handle...
            let mut buf = [0u8; 4];
            let err = ctx.read_slot(stale, 0, &mut buf).unwrap_err();
            assert!(
                matches!(&err, LpfError::Illegal(m) if m.contains("earlier job epoch")),
                "{err:?}"
            );
            // ...including the put/get enqueue validation
            ctx.resize_memory_register(1).unwrap();
            ctx.resize_message_queue(4).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let fresh = ctx.register_global(8).unwrap();
            let err = ctx.put(stale, 0, 0, fresh, 0, 4, MSG_DEFAULT).unwrap_err();
            assert!(matches!(err, LpfError::Illegal(_)), "{err:?}");
            // a stale handle can never alias a live slot, even at the same
            // index: generations are monotonic across the job boundary
            assert!(stale.index() != fresh.index() || stale != fresh);
        },
        Args::none(),
    )
    .unwrap();
}

#[test]
fn panic_payload_and_pid_reach_the_submitter() {
    let pool = Pool::new(Platform::shared(), 3);
    let err = pool
        .exec(
            |ctx, _| {
                if ctx.pid() == 2 {
                    panic!("graph shard 2 out of range");
                }
            },
            Args::none(),
        )
        .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("graph shard 2 out of range"), "payload lost: {msg}");
    assert!(msg.contains("pid 2"), "pid lost: {msg}");
    // the same propagation holds through the one-shot exec sugar
    let root = Root::new(Platform::shared()).with_max_procs(2);
    let err = exec(
        &root,
        2,
        |ctx, _| {
            if ctx.pid() == 1 {
                panic!("boom {}", 41 + 1);
            }
        },
        Args::none(),
    )
    .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("boom 42") && msg.contains("pid 1"), "{msg}");
}

#[test]
fn netsim_clocks_restart_per_job() {
    let pool = Pool::new(Platform::rdma(), 3);
    let job = |ctx: &mut Context, _: Args| -> f64 {
        ctx.resize_memory_register(1).unwrap();
        ctx.resize_message_queue(4).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        ctx.sim_time_ns().unwrap()
    };
    let first = pool.exec(job, Args::none()).unwrap();
    let second = pool.exec(job, Args::none()).unwrap();
    // deterministic netsim + per-job clock reset: identical timelines
    assert_eq!(first, second, "clocks must restart at every job boundary");
}
