//! Integration over the PJRT runtime: artifacts built by `make artifacts`
//! load, compile and produce numerics matching the Rust oracles. Skipped
//! (with a loud warning) when artifacts have not been built.

use lpf::fft::local;
use lpf::fft::plan::FftPlan;
use lpf::runtime::{Runtime, Tensor};
use lpf::util::rng::XorShift64;

fn runtime() -> Option<std::sync::Arc<Runtime>> {
    match Runtime::global() {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("SKIP runtime_e2e: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShift64::new(seed);
    (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect()
}

#[test]
fn manifest_lists_expected_families() {
    let Some(rt) = runtime() else { return };
    let names: Vec<&str> = rt.manifest().names().collect();
    for family in ["fft_local_", "cmul_", "fft_batch_", "fft_full_", "spmv_", "pr_update_"] {
        assert!(
            names.iter().any(|n| n.starts_with(family)),
            "missing artifact family {family}"
        );
    }
}

#[test]
fn fft_local_artifact_matches_rust_fft() {
    let Some(rt) = runtime() else { return };
    let m = 1024;
    let plan = FftPlan::new(m).unwrap();
    let re = rand_f32(m, 1);
    let im = rand_f32(m, 2);
    let out = rt
        .run(
            &format!("fft_local_{m}"),
            vec![
                Tensor::F32(re.clone()),
                Tensor::F32(im.clone()),
                Tensor::I32(plan.perm_i32().unwrap()),
                Tensor::F32(plan.tw_re.clone()),
                Tensor::F32(plan.tw_im.clone()),
            ],
        )
        .unwrap();
    let (want_re, want_im) = local::fft(&plan, &re, &im).unwrap();
    let got_re = out[0].as_f32().unwrap();
    let got_im = out[1].as_f32().unwrap();
    let tol = 1e-3 * (m as f32).sqrt();
    for k in 0..m {
        assert!((got_re[k] - want_re[k]).abs() < tol, "re[{k}]");
        assert!((got_im[k] - want_im[k]).abs() < tol, "im[{k}]");
    }
}

#[test]
fn cmul_artifact_is_complex_multiply() {
    let Some(rt) = runtime() else { return };
    let m = 256;
    let (a_re, a_im) = (rand_f32(m, 3), rand_f32(m, 4));
    let (b_re, b_im) = (rand_f32(m, 5), rand_f32(m, 6));
    let out = rt
        .run(
            &format!("cmul_{m}"),
            vec![
                Tensor::F32(a_re.clone()),
                Tensor::F32(a_im.clone()),
                Tensor::F32(b_re.clone()),
                Tensor::F32(b_im.clone()),
            ],
        )
        .unwrap();
    let got_re = out[0].as_f32().unwrap();
    let got_im = out[1].as_f32().unwrap();
    for k in 0..m {
        let er = a_re[k] * b_re[k] - a_im[k] * b_im[k];
        let ei = a_re[k] * b_im[k] + a_im[k] * b_re[k];
        assert!((got_re[k] - er).abs() < 1e-4);
        assert!((got_im[k] - ei).abs() < 1e-4);
    }
}

#[test]
fn spmv_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    // use the aot-built shape (see aot.py): logn=13, p=4
    let (nnz, n_in, n_out) = (8 * (1 << 13) / 4, 1 << 13, (1 << 13) / 4);
    let name = format!("spmv_{nnz}_{n_in}_{n_out}");
    if rt.manifest().get(&name).is_none() {
        eprintln!("SKIP spmv shape {name}");
        return;
    }
    let mut rng = XorShift64::new(9);
    let vals: Vec<f32> = (0..nnz).map(|_| rng.unit_f64() as f32).collect();
    let cols: Vec<i32> = (0..nnz).map(|_| rng.below(n_in as u64) as i32).collect();
    let rows: Vec<i32> = (0..nnz).map(|_| rng.below(n_out as u64) as i32).collect();
    let x = rand_f32(n_in, 10);
    let out = rt
        .run(
            &name,
            vec![
                Tensor::F32(vals.clone()),
                Tensor::I32(cols.clone()),
                Tensor::I32(rows.clone()),
                Tensor::F32(x.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    let mut want = vec![0f32; n_out];
    for e in 0..nnz {
        want[rows[e] as usize] += vals[e] * x[cols[e] as usize];
    }
    for k in 0..n_out {
        assert!((got[k] - want[k]).abs() < 1e-2, "y[{k}]: {} vs {}", got[k], want[k]);
    }
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(rt) = runtime() else { return };
    let err = rt.run("cmul_256", vec![Tensor::F32(vec![0.0; 8])]).unwrap_err();
    assert!(matches!(err, lpf::core::LpfError::Illegal(_)));
    let err = rt.run("no_such_artifact", vec![]).unwrap_err();
    assert!(matches!(err, lpf::core::LpfError::Illegal(_)));
}

#[test]
fn concurrent_runs_from_many_threads() {
    let Some(rt) = runtime() else { return };
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let rt = rt.clone();
            s.spawn(move || {
                let m = 256;
                let a = rand_f32(m, 100 + t);
                let out = rt
                    .run(
                        &format!("cmul_{m}"),
                        vec![
                            Tensor::F32(a.clone()),
                            Tensor::F32(vec![0.0; m]),
                            Tensor::F32(vec![2.0; m]),
                            Tensor::F32(vec![0.0; m]),
                        ],
                    )
                    .unwrap();
                let got = out[0].as_f32().unwrap();
                for k in 0..m {
                    assert!((got[k] - 2.0 * a[k]).abs() < 1e-5);
                }
            });
        }
    });
}
