//! Sync-engine invariants (ISSUE 2):
//!
//! 1. resolved `WriteSeg`s are pairwise non-overlapping, stay inside their
//!    descriptor, and cover exactly the winning bytes of a sequential
//!    CRCW replay oracle;
//! 2. request coalescing never changes post-sync memory contents;
//! 3. deliberately conflicting h-relations produce bit-identical CRCW
//!    outcomes on shared / msg / rdma / hybrid;
//! 4. `split_requests` returns exactly-p-sized tables and rejects
//!    out-of-range pids.

use lpf::core::{Args, Pid, MSG_DEFAULT, SYNC_DEFAULT};
use lpf::ctx::{exec, Platform, Root};
use lpf::fabric::net::{MetaAlgo, NetFabric, Topology};
use lpf::fabric::shared::SharedFabric;
use lpf::fabric::{split_requests, Fabric};
use lpf::memory::SlotStorage;
use lpf::netsim::Personality;
use lpf::queue::{PutReq, Request};
use lpf::sync::conflict::{resolve_writes, WriteDesc};
use lpf::util::rng::XorShift64;
use std::sync::Arc;

// ------------------------------------------------------------ invariant 1

fn random_descs(rng: &mut XorShift64, size: usize) -> Vec<WriteDesc> {
    let n = 1 + rng.below_usize(14);
    (0..n)
        .map(|i| {
            let off = rng.below_usize(size - 1);
            WriteDesc {
                slot_kind: lpf::core::SlotKind::Global,
                slot_index: rng.below(2) as u32,
                dst_off: off,
                len: rng.below_usize(size - off), // may be 0
                src_pid: rng.below(5) as Pid,
                seq: i as u32,
                tag: i as u64,
            }
        })
        .collect()
}

#[test]
fn segments_are_disjoint_in_bounds_and_cover_winning_bytes() {
    let mut rng = XorShift64::new(0x1ead_beef);
    let size = 64;
    for case in 0..400 {
        let descs = random_descs(&mut rng, size);
        let segs = resolve_writes(&descs);
        // each segment stays inside its descriptor, delta consistent
        for s in &segs {
            let d = &descs[s.desc];
            assert!(s.len > 0, "case {case}: empty segment");
            assert!(s.dst_off >= d.dst_off && s.dst_off + s.len <= d.dst_off + d.len);
            assert_eq!(s.dst_off - d.dst_off, s.src_delta, "case {case}");
        }
        // per (slot_index): pairwise disjoint and equal to the oracle
        for slot in 0..2u32 {
            // oracle: byte-by-byte replay in ascending (src_pid, seq)
            let mut oracle: Vec<Option<usize>> = vec![None; size];
            let mut order: Vec<usize> = (0..descs.len()).collect();
            order.sort_by_key(|&i| ((descs[i].src_pid as u64) << 32) | descs[i].seq as u64);
            for &i in &order {
                let d = &descs[i];
                if d.slot_index != slot {
                    continue;
                }
                for b in d.dst_off..d.dst_off + d.len {
                    oracle[b] = Some(i);
                }
            }
            let mut covered: Vec<Option<usize>> = vec![None; size];
            for s in segs.iter().filter(|s| descs[s.desc].slot_index == slot) {
                for b in s.dst_off..s.dst_off + s.len {
                    assert!(covered[b].is_none(), "case {case}: overlapping segments at {b}");
                    covered[b] = Some(s.desc);
                }
            }
            assert_eq!(covered, oracle, "case {case} slot {slot}: wrong winners");
        }
    }
}

// ------------------------------------------------------------ invariant 2

/// A put batch with coalescible runs and deliberate cross-process overlap:
/// every process writes `runs` runs of `k` contiguous puts each into pid 0,
/// at random (overlapping) bases, plus a few scattered non-contiguous puts.
fn coalescing_scenario(rng: &mut XorShift64, p: Pid) -> Vec<Vec<(usize, usize, usize)>> {
    // per pid: (src_off, dst_off, len) in issue order; src in [64,128),
    // dst in [0,64) — read/write disjoint by construction
    (0..p)
        .map(|_| {
            let mut reqs = Vec::new();
            for _ in 0..1 + rng.below_usize(3) {
                // a contiguous run: k puts of `step` bytes
                let k = 1 + rng.below_usize(4);
                let step = 1 + rng.below_usize(4);
                let src0 = 64 + rng.below_usize(64 - k * step);
                let dst0 = rng.below_usize(64 - k * step);
                for i in 0..k {
                    reqs.push((src0 + i * step, dst0 + i * step, step));
                }
            }
            for _ in 0..rng.below_usize(3) {
                let len = 1 + rng.below_usize(8);
                reqs.push((64 + rng.below_usize(64 - len), rng.below_usize(64 - len), len));
            }
            reqs
        })
        .collect()
}

fn run_scenario_on(fab: Arc<dyn Fabric>, puts: &[Vec<(usize, usize, usize)>]) -> Vec<u8> {
    let p = fab.p();
    let mut out = vec![0u8; 128];
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..p)
            .map(|pid| {
                let fab = fab.clone();
                let mine = puts[pid as usize].clone();
                s.spawn(move || {
                    let slot = fab.register_of(pid).with_mut(|r| {
                        r.resize(2).unwrap();
                        r.activate_pending();
                        let st = SlotStorage::new(128).unwrap();
                        let init: Vec<u8> =
                            (0..128).map(|i| (pid as usize * 31 + i * 7) as u8).collect();
                        unsafe { st.bytes_mut().copy_from_slice(&init) };
                        r.register_global(st).unwrap()
                    });
                    fab.barrier(pid).unwrap(); // all slots registered
                    let reqs: Vec<Request> = mine
                        .iter()
                        .map(|&(src_off, dst_off, len)| {
                            Request::Put(PutReq {
                                src_slot: slot,
                                src_off,
                                dst_pid: 0,
                                dst_slot: slot,
                                dst_off,
                                len,
                                attr: MSG_DEFAULT,
                            })
                        })
                        .collect();
                    fab.sync(pid, &reqs, SYNC_DEFAULT).unwrap();
                    if pid == 0 {
                        let st = fab.register_of(0).resolve(slot).unwrap();
                        Some(unsafe { st.bytes().to_vec() })
                    } else {
                        None
                    }
                })
            })
            .collect();
        for h in handles {
            if let Some(bytes) = h.join().unwrap() {
                out = bytes;
            }
        }
    });
    out
}

#[test]
fn coalescing_never_changes_post_sync_memory() {
    let mut rng = XorShift64::new(0xC0A1);
    for case in 0..25 {
        let p = 2 + rng.below(3) as Pid;
        let sc = coalescing_scenario(&mut rng, p);
        // shared backend, coalescing on vs off
        let on = SharedFabric::new(p, false);
        on.set_coalescing(true);
        let off = SharedFabric::new(p, false);
        off.set_coalescing(false);
        let mem_on = run_scenario_on(on, &sc);
        let mem_off = run_scenario_on(off, &sc);
        assert_eq!(mem_on, mem_off, "case {case}: shared coalescing changed memory");
        // distributed backend too (trim notices address coalesced seqs)
        let net_on = NetFabric::with_config(
            p,
            "rdma",
            Personality::ibverbs(),
            Topology::distributed(),
            MetaAlgo::Direct,
            false,
        );
        let net_off = NetFabric::with_config(
            p,
            "rdma",
            Personality::ibverbs(),
            Topology::distributed(),
            MetaAlgo::Direct,
            false,
        );
        net_off.set_coalescing(false);
        let mem_net_on = run_scenario_on(net_on, &sc);
        let mem_net_off = run_scenario_on(net_off, &sc);
        assert_eq!(mem_net_on, mem_off, "case {case}: net/shared diverged");
        assert_eq!(mem_net_on, mem_net_off, "case {case}: net coalescing changed memory");
    }
}

// ------------------------------------------------------------ invariant 3

#[test]
fn conflicting_writes_are_bit_identical_across_backends() {
    // Deliberate conflicts: nested, partially overlapping, and same-source
    // repeated writes onto pid 0's slot, plus a get in the same superstep.
    let program = |ctx: &mut lpf::Context, _: Args| {
        let p = ctx.p();
        ctx.resize_memory_register(1).unwrap();
        ctx.resize_message_queue(16).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let slot = ctx.register_global(96).unwrap();
        let init: Vec<u8> = (0..96).map(|i| (ctx.pid() as usize * 13 + i) as u8).collect();
        ctx.write_slot(slot, 0, &init).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let pid = ctx.pid();
        // everyone writes overlapping ranges of pid 0's [0,48)
        ctx.put(slot, 48, 0, slot, (pid as usize * 5) % 24, 20, MSG_DEFAULT).unwrap();
        ctx.put(slot, 52, 0, slot, 8, 12, MSG_DEFAULT).unwrap(); // same source, later seq
        if pid == p - 1 {
            ctx.put(slot, 56, 0, slot, 0, 40, MSG_DEFAULT).unwrap(); // big outer write
        }
        if pid == 1 {
            // a get in the same superstep: writes pid 1's [40,48) locally,
            // disjoint from pid 1's own put-source reads in [48,64)
            ctx.get(0, slot, 80, slot, 40, 8, MSG_DEFAULT).unwrap();
        }
        ctx.sync(SYNC_DEFAULT).unwrap();
        let mut out = vec![0u8; 96];
        ctx.read_slot(slot, 0, &mut out).unwrap();
        out
    };
    let mut results: Vec<(&str, Vec<Vec<u8>>)> = Vec::new();
    for (name, plat) in [
        ("shared", Platform::shared().checked(false)),
        ("rdma", Platform::rdma()),
        ("msg", Platform::msg()),
        ("hybrid", Platform::hybrid(2)),
    ] {
        let root = Root::new(plat).with_max_procs(4);
        let outs = exec(&root, 4, program, Args::none()).unwrap();
        results.push((name, outs));
    }
    let (base_name, base) = &results[0];
    for (name, outs) in &results[1..] {
        assert_eq!(outs, base, "{name} diverged from {base_name}");
    }
}

// ------------------------------------------------------------ invariant 4

#[test]
fn split_requests_tables_are_exactly_p_sized() {
    let slot = |i: u32| {
        // build a handle through the public API: register on a throwaway
        // fabric so kind/index/gen are consistent
        let fab = SharedFabric::new(1, false);
        fab.register_of(0).with_mut(|r| {
            r.resize(i as usize + 1).unwrap();
            r.activate_pending();
            let mut last = None;
            for _ in 0..=i {
                last = Some(r.register_global(SlotStorage::new(8).unwrap()).unwrap());
            }
            last.unwrap()
        })
    };
    let s0 = slot(0);
    let reqs = vec![
        Request::Put(PutReq {
            src_slot: s0,
            src_off: 0,
            dst_pid: 1,
            dst_slot: s0,
            dst_off: 0,
            len: 4,
            attr: MSG_DEFAULT,
        }),
        Request::Get(lpf::queue::GetReq {
            src_pid: 3,
            src_slot: s0,
            src_off: 0,
            dst_slot: s0,
            dst_off: 4,
            len: 2,
            attr: MSG_DEFAULT,
        }),
    ];
    let (puts, gets) = split_requests(0, 5, &reqs).unwrap();
    assert_eq!(puts.len(), 5);
    assert_eq!(gets.len(), 5);
    assert_eq!(puts[1].len(), 1);
    assert_eq!(gets[3].len(), 1);
    assert!(puts[0].is_empty() && puts[2].is_empty() && puts[4].is_empty());
    // out-of-range pid rejected up front (no more defensive call-site checks)
    assert!(split_requests(0, 1, &reqs).is_err());
}
