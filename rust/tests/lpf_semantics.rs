//! Integration: LPF semantics must be identical on every backend.
//!
//! The paper's central claim is that one algorithm runs unchanged on all
//! four implementations; these tests execute the same SPMD programs on
//! shared / rdma / msg / hybrid fabrics and require byte-identical
//! results, including the deterministic CRCW conflict-resolution order.

use lpf::core::{Args, LpfError, SyncAttr, MSG_DEFAULT, SYNC_DEFAULT};
use lpf::ctx::{exec, Context, Platform, Root};

fn all_platforms() -> Vec<(&'static str, Platform)> {
    vec![
        ("shared", Platform::shared().checked(true)),
        ("rdma", Platform::rdma().checked(true)),
        ("msg", Platform::msg().checked(true)),
        ("hybrid", Platform::hybrid(2).checked(true)),
    ]
}

/// Run one SPMD program on every backend and collect outputs.
fn on_all_backends<O: Send + PartialEq + std::fmt::Debug>(
    p: u32,
    f: impl Fn(&mut Context, Args) -> O + Sync + Copy,
) -> Vec<(&'static str, Vec<O>)> {
    all_platforms()
        .into_iter()
        .map(|(name, plat)| {
            let root = Root::new(plat).with_max_procs(p);
            (name, exec(&root, p, f, Args::none()).unwrap())
        })
        .collect()
}

#[test]
fn allgather_identical_across_backends() {
    let results = on_all_backends(4, |ctx, _| {
        ctx.resize_memory_register(2).unwrap();
        ctx.resize_message_queue(2 * ctx.p() as usize).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let mine = ctx.register_global(8).unwrap();
        let all = ctx.register_global(8 * ctx.p() as usize).unwrap();
        ctx.write_typed(mine, 0, &[0xAB00u64 + ctx.pid() as u64]).unwrap();
        for k in 0..ctx.p() {
            ctx.put(mine, 0, k, all, 8 * ctx.pid() as usize, 8, MSG_DEFAULT).unwrap();
        }
        ctx.sync(SYNC_DEFAULT).unwrap();
        let mut v = vec![0u64; ctx.p() as usize];
        ctx.read_typed(all, 0, &mut v).unwrap();
        v
    });
    let reference = results[0].1.clone();
    for (name, got) in &results {
        assert_eq!(got, &reference, "backend {name} diverged");
    }
}

#[test]
fn crcw_winner_identical_across_backends() {
    // all pids write overlapping ranges into pid 0; the deterministic
    // winner (highest (pid, seq)) must agree across backends byte-for-byte
    let results = on_all_backends(4, |ctx, _| {
        ctx.resize_memory_register(2).unwrap();
        ctx.resize_message_queue(4 * ctx.p() as usize).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let src = ctx.register_global(16).unwrap();
        let dst = ctx.register_global(16).unwrap();
        let fill = vec![ctx.pid() as u8 + 1; 16];
        ctx.write_slot(src, 0, &fill).unwrap();
        // pid k writes [k, k+8) — staggered overlaps
        ctx.put(src, 0, 0, dst, ctx.pid() as usize * 2, 8, MSG_DEFAULT).unwrap();
        // a second, same-pid later write over part of the first
        ctx.put(src, 8, 0, dst, ctx.pid() as usize * 2 + 1, 2, MSG_DEFAULT).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let mut out = vec![0u8; 16];
        if ctx.pid() == 0 {
            ctx.read_slot(dst, 0, &mut out).unwrap();
        }
        out
    });
    let reference = results[0].1[0].clone();
    assert!(reference.iter().any(|&b| b != 0), "something was written");
    for (name, got) in &results {
        assert_eq!(got[0], reference, "backend {name} resolved CRCW differently");
    }
}

#[test]
fn gets_identical_across_backends() {
    let results = on_all_backends(3, |ctx, _| {
        ctx.resize_memory_register(2).unwrap();
        ctx.resize_message_queue(2 * ctx.p() as usize).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let data = ctx.register_global(8).unwrap();
        let got = ctx.register_global(8 * ctx.p() as usize).unwrap();
        ctx.write_typed(data, 0, &[(ctx.pid() as u64 + 7) * 11]).unwrap();
        for k in 0..ctx.p() {
            ctx.get(k, data, 0, got, 8 * k as usize, 8, MSG_DEFAULT).unwrap();
        }
        ctx.sync(SYNC_DEFAULT).unwrap();
        let mut v = vec![0u64; ctx.p() as usize];
        ctx.read_typed(got, 0, &mut v).unwrap();
        v
    });
    let reference = results[0].1.clone();
    assert_eq!(reference[0], vec![77, 88, 99]);
    for (name, got) in &results {
        assert_eq!(got, &reference, "backend {name} diverged");
    }
}

#[test]
fn multi_superstep_pipeline_identical() {
    // shift a token around the ring for p supersteps
    let results = on_all_backends(4, |ctx, _| {
        let p = ctx.p();
        ctx.resize_memory_register(2).unwrap();
        ctx.resize_message_queue(4).unwrap();
        ctx.sync(SYNC_DEFAULT).unwrap();
        let cur = ctx.register_global(8).unwrap();
        let nxt = ctx.register_global(8).unwrap();
        ctx.write_typed(cur, 0, &[ctx.pid() as u64]).unwrap();
        for _ in 0..p {
            ctx.put(cur, 0, (ctx.pid() + 1) % p, nxt, 0, 8, MSG_DEFAULT).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let mut v = [0u64];
            ctx.read_typed(nxt, 0, &mut v).unwrap();
            ctx.write_typed(cur, 0, &[v[0] + 1]).unwrap();
        }
        let mut v = [0u64];
        ctx.read_typed(cur, 0, &mut v).unwrap();
        v[0]
    });
    // token returns home having been incremented p times
    let reference = results[0].1.clone();
    for (pid, &v) in reference.iter().enumerate() {
        assert_eq!(v, pid as u64 + 4, "ring arithmetic");
    }
    for (name, got) in &results {
        assert_eq!(got, &reference, "backend {name} diverged");
    }
}

#[test]
fn split_phase_misuse_is_clean_illegal_on_all_backends() {
    // Every misuse of the split-phase pair must be a *purely local*
    // `Illegal` — returned before any barrier, so it can never deadlock
    // the team or corrupt the in-flight exchange — and the context must
    // stay fully usable afterwards.
    for (name, plat) in all_platforms() {
        let root = Root::new(plat).with_max_procs(2);
        exec(
            &root,
            2,
            |ctx, _| {
                ctx.resize_memory_register(2).unwrap();
                ctx.resize_message_queue(4).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                let src = ctx.register_global(8).unwrap();
                let dst = ctx.register_global(8).unwrap();
                // end without begin: local Illegal, nothing in flight
                assert!(matches!(ctx.sync_end(), Err(LpfError::Illegal(_))));
                let peer = (ctx.pid() + 1) % 2;
                ctx.write_typed(src, 0, &[ctx.pid() as u64 + 1]).unwrap();
                ctx.put(src, 0, peer, dst, 0, 8, MSG_DEFAULT).unwrap();
                ctx.sync_begin(SYNC_DEFAULT).unwrap();
                // inside the window: begin again, bulk sync, put, get —
                // each a clean Illegal that leaves the exchange untouched
                assert!(matches!(ctx.sync_begin(SYNC_DEFAULT), Err(LpfError::Illegal(_))));
                assert!(matches!(ctx.sync(SYNC_DEFAULT), Err(LpfError::Illegal(_))));
                assert!(matches!(
                    ctx.put(src, 0, peer, dst, 0, 8, MSG_DEFAULT),
                    Err(LpfError::Illegal(_))
                ));
                assert!(matches!(
                    ctx.get(peer, src, 0, dst, 0, 8, MSG_DEFAULT),
                    Err(LpfError::Illegal(_))
                ));
                ctx.sync_end().unwrap();
                // the exchange delivered despite the misuse attempts
                let mut v = [0u64];
                ctx.read_typed(dst, 0, &mut v).unwrap();
                assert_eq!(v[0], peer as u64 + 1);
                // a second end is Illegal again once quiescent
                assert!(matches!(ctx.sync_end(), Err(LpfError::Illegal(_))));
                // and an ordinary bulk superstep still works
                ctx.sync(SYNC_DEFAULT).unwrap();
            },
            Args::none(),
        )
        .unwrap_or_else(|e| panic!("backend {name}: {e}"));
    }
}

#[test]
fn dangling_sync_begin_at_exit_fails_clean_not_deadlock() {
    // Returning from the SPMD function with a split superstep still in
    // flight is misuse; the never-deadlock rule says it must surface as
    // a clean error on every backend, not wedge the team at a barrier.
    for (name, plat) in all_platforms() {
        let root = Root::new(plat).with_max_procs(2);
        let res = exec(
            &root,
            2,
            |ctx, _| {
                ctx.resize_memory_register(1).unwrap();
                ctx.resize_message_queue(2).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                ctx.sync_begin(SYNC_DEFAULT).unwrap();
                // no sync_end: the harness must refuse the dangling begin
            },
            Args::none(),
        );
        let err = res.expect_err("dangling begin must fail");
        assert!(!err.is_mitigable(), "backend {name}: {err:?}");
    }
}

#[test]
fn sync_attr_threads_through_both_entry_points() {
    // `assume_no_conflicts` is a contract, not a hint the engine may
    // drop: a conflict-free exchange must deliver identical bytes with
    // the attribute asserted through the bulk entry point and through
    // the split-phase pair.
    let nc = SyncAttr { assume_no_conflicts: true };
    for split in [false, true] {
        let results = on_all_backends(4, move |ctx, _| {
            let p = ctx.p();
            ctx.resize_memory_register(2).unwrap();
            ctx.resize_message_queue(2 * p as usize).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let mine = ctx.register_global(8).unwrap();
            let all = ctx.register_global(8 * p as usize).unwrap();
            ctx.write_typed(mine, 0, &[0xC0DEu64 + ctx.pid() as u64]).unwrap();
            // disjoint destinations: genuinely conflict-free
            for k in 0..p {
                ctx.put(mine, 0, k, all, 8 * ctx.pid() as usize, 8, MSG_DEFAULT).unwrap();
            }
            if split {
                ctx.sync_begin(nc).unwrap();
                ctx.sync_end().unwrap();
            } else {
                ctx.sync(nc).unwrap();
            }
            let mut v = vec![0u64; p as usize];
            ctx.read_typed(all, 0, &mut v).unwrap();
            v
        });
        let want: Vec<u64> = (0..4).map(|k| 0xC0DEu64 + k).collect();
        for (name, got) in &results {
            for (pid, v) in got.iter().enumerate() {
                assert_eq!(v, &want, "backend {name} pid {pid} split={split}");
            }
        }
    }
}

#[test]
fn queue_capacity_discipline_shrink_deferred_and_seq_space_bounded() {
    // ISSUE 4 satellites, pinned on the raw public `MsgQueue` type — the
    // surface where the discipline can actually be violated: a resize may
    // never invalidate queued requests (shrink defers to the drained
    // fence), and the capacity may never exceed the u32 wire
    // sequence-number space. (`Context::sync` drains the queue before it
    // activates capacities, so the integrated path reaches the fence with
    // an empty queue by construction; direct `MsgQueue` users get the
    // same guarantee from the deferral floor pinned here.)
    use lpf::fabric::shared::SharedFabric;
    use lpf::fabric::Fabric;
    use lpf::memory::SlotStorage;
    use lpf::queue::MsgQueue;
    let fab = SharedFabric::new(1, false);
    let slot = fab.register_of(0).with_mut(|r| {
        r.resize(1).unwrap();
        r.activate_pending();
        r.register_global(SlotStorage::new(8).unwrap()).unwrap()
    });
    let mut q = MsgQueue::new();
    q.resize(3).unwrap();
    q.activate_pending();
    for _ in 0..3 {
        q.push_put(lpf::queue::PutReq {
            src_slot: slot,
            src_off: 0,
            dst_pid: 0,
            dst_slot: slot,
            dst_off: 4,
            len: 1,
            attr: MSG_DEFAULT,
        })
        .unwrap();
    }
    q.resize(1).unwrap();
    q.activate_pending();
    assert!(q.capacity() >= q.len(), "a fence must not strand queued requests");
    q.clear();
    q.activate_pending();
    assert_eq!(q.capacity(), 1, "the shrink lands once the queue drained");
    #[cfg(target_pointer_width = "64")]
    {
        let err = q.resize(u32::MAX as usize + 1).unwrap_err();
        assert!(matches!(err, LpfError::Illegal(_)), "{err:?}");
    }
}

#[test]
fn capacity_errors_mitigable_on_all_backends() {
    for (name, plat) in all_platforms() {
        let root = Root::new(plat).with_max_procs(2);
        exec(
            &root,
            2,
            |ctx, _| {
                // no capacity yet: registration must fail mitigably
                let err = ctx.register_global(8).unwrap_err();
                assert!(err.is_mitigable());
                ctx.resize_memory_register(1).unwrap();
                ctx.resize_message_queue(1).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                ctx.register_global(8).unwrap();
            },
            Args::none(),
        )
        .unwrap_or_else(|e| panic!("backend {name}: {e}"));
    }
}

#[test]
fn illegal_read_write_overlap_rejected_on_checked_backends() {
    for (name, plat) in all_platforms() {
        let root = Root::new(plat).with_max_procs(2);
        let res = exec(
            &root,
            2,
            |ctx, _| {
                ctx.resize_memory_register(1).unwrap();
                ctx.resize_message_queue(4).unwrap();
                ctx.sync(SYNC_DEFAULT).unwrap();
                let s = ctx.register_global(8).unwrap();
                // read [0,8) of own slot while peer writes [0,8) — illegal
                ctx.put(s, 0, (ctx.pid() + 1) % 2, s, 0, 8, MSG_DEFAULT).unwrap();
                match ctx.sync(SYNC_DEFAULT) {
                    Err(LpfError::Illegal(_)) | Err(LpfError::PeerAborted { .. }) => true,
                    other => panic!("backend expected illegality, got {other:?}"),
                }
            },
            Args::none(),
        );
        // exec as a whole may report the abort; both outcomes are fine as
        // long as no backend silently accepts the program
        match res {
            Ok(flags) => assert!(flags.iter().all(|&f| f), "backend {name}"),
            Err(e) => assert!(!e.is_mitigable(), "backend {name}: {e}"),
        }
    }
}
