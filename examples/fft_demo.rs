//! The immortal FFT demo (paper §4.2): the Inda–Bisseling BSP FFT through
//! the BSPlib-on-LPF layer, with process-local compute on PJRT artifacts
//! when available (`make artifacts`), and verification against the serial
//! oracle plus a comparison against both Fig.-3 baselines.
//!
//! Run: `cargo run --release --example fft_demo -- [log2_n] [p]`

use lpf::bsplib::Bsp;
use lpf::core::Args;
use lpf::ctx::{exec, Platform, Root};
use lpf::fft::baseline::{PortableFft, VendorFft};
use lpf::fft::bsp::{Backend, BspFft};
use lpf::fft::plan::FftPlan;
use lpf::fft::local;
use lpf::runtime::Runtime;
use lpf::util::rng::XorShift64;
use std::time::Instant;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let k: u32 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let p: u32 = argv.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let n = 1usize << k;
    println!("immortal BSP FFT: n = 2^{k} = {n}, p = {p}");

    let runtime = Runtime::global().ok();
    let backend = match &runtime {
        Some(rt) => {
            println!("backend: PJRT artifacts ({} in manifest)", rt.manifest().len());
            Backend::Artifacts(rt.clone())
        }
        None => {
            println!("backend: native (run `make artifacts` for the PJRT path)");
            Backend::Native
        }
    };

    // global input
    let mut rng = XorShift64::new(2026);
    let g_re: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();
    let g_im: Vec<f32> = (0..n).map(|_| rng.unit_f64() as f32 - 0.5).collect();

    // distributed immortal FFT
    let root = Root::new(Platform::shared()).with_max_procs(p);
    let (g_re2, g_im2) = (g_re.clone(), g_im.clone());
    let t = Instant::now();
    let outs = exec(
        &root,
        p,
        move |ctx, _| {
            let r = ctx.pid();
            let pp = ctx.p();
            let m = n / pp as usize;
            let mut bsp = Bsp::begin(ctx, 8, 4 * pp as usize + 8).unwrap();
            bsp.sync().unwrap();
            let mut fft = BspFft::new(&mut bsp, n, backend.clone()).unwrap();
            bsp.sync().unwrap();
            let re: Vec<f32> = (0..m).map(|j| g_re2[r as usize + pp as usize * j]).collect();
            let im: Vec<f32> = (0..m).map(|j| g_im2[r as usize + pp as usize * j]).collect();
            let t = Instant::now();
            let (o_re, o_im) = fft.run(&mut bsp, &re, &im).unwrap();
            let secs = t.elapsed().as_secs_f64();
            let blk = m / pp as usize;
            let mut placed = vec![(0usize, 0f32, 0f32); m];
            for k2 in 0..blk {
                for k1 in 0..pp as usize {
                    placed[k2 * pp as usize + k1] = (
                        fft.global_index(k2, k1),
                        o_re[k2 * pp as usize + k1],
                        o_im[k2 * pp as usize + k1],
                    );
                }
            }
            bsp.end().unwrap();
            (placed, secs)
        },
        Args::none(),
    )
    .unwrap();
    let wall = t.elapsed().as_secs_f64();

    // verify against the serial oracle
    let plan = FftPlan::new(n).unwrap();
    let (want_re, want_im) = local::fft(&plan, &g_re, &g_im).unwrap();
    let mut max_err = 0f32;
    for (placed, _) in &outs {
        for &(gidx, re, im) in placed {
            max_err = max_err.max((re - want_re[gidx]).abs()).max((im - want_im[gidx]).abs());
        }
    }
    let inner_secs = outs.iter().map(|(_, s)| *s).fold(0.0, f64::max);
    println!("BSP FFT: {:.3} ms (incl. spawn {:.3} ms), max |err| = {max_err:.2e}", inner_secs * 1e3, wall * 1e3);
    assert!(max_err < 1e-2 * (n as f32).sqrt(), "verification failed");

    // baselines
    if let Some(rt) = &runtime {
        let v = VendorFft::new(n, rt.clone());
        let _ = v.run(g_re.clone(), g_im.clone()).unwrap();
        let t = Instant::now();
        let _ = v.run(g_re.clone(), g_im.clone()).unwrap();
        println!("vendor-proxy (fused XLA FFT): {:.3} ms", t.elapsed().as_secs_f64() * 1e3);
    }
    let f = PortableFft::new(n).unwrap();
    let t = Instant::now();
    let _ = f.run(&g_re, &g_im).unwrap();
    println!("portable-proxy (rust radix-2): {:.3} ms", t.elapsed().as_secs_f64() * 1e3);
    println!("OK");
}
