//! END-TO-END DRIVER (paper §4.3, Table 4): the full system on a real
//! small workload, proving all layers compose:
//!
//! 1. generate an R-MAT web-like graph, round-trip it through
//!    MatrixMarket (the paper's interchange format);
//! 2. boot sparksim (driver + worker threads) and run the **pure-Spark**
//!    PageRank (canonical: no dangling handling, no convergence check);
//! 3. from the *same* workers, bootstrap LPF interop exactly as §4.3:
//!    collect hostnames → dedupe → broadcast → derive (p, s, master) →
//!    `Init::over_master` → `hook` — and run the **LPF GraphBLAS
//!    PageRank**, whose SpMV + rank-update execute PJRT artifacts
//!    (L1 Pallas kernels lowered through L2 JAX) when available;
//! 4. print Table-4-style rows and verify the LPF ranks against the
//!    serial oracle.
//!
//! Run: `make artifacts && cargo run --release --example spark_pagerank`

use std::time::Instant;

use lpf::graphblas::{pagerank_serial, Compute};
use lpf::graphgen::{read_matrix_market, rmat, write_matrix_market, RmatConfig};
use lpf::runtime::Runtime;
use lpf::sparksim::pagerank::{accelerated_pagerank, pure_spark_pagerank};
use lpf::sparksim::Spark;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let scale: u32 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(13);
    let workers: usize = argv.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let iters: u32 = argv.get(3).and_then(|s| s.parse().ok()).unwrap_or(10);

    // ---- 1. workload: R-MAT graph through MatrixMarket
    println!("== generating rmat-{scale} (2^{scale} vertices, ~8 edges/vertex)");
    let g0 = rmat(&RmatConfig::new(scale, 8, 42));
    let mm = std::env::temp_dir().join(format!("lpf_rmat_{scale}.mtx"));
    write_matrix_market(&g0, &mm).expect("write mm");
    let g = read_matrix_market(&mm).expect("read mm");
    assert_eq!(g.n, g0.n);
    println!(
        "   n = {}, nnz = {}, dangling = {} ({:.1} MB MatrixMarket)",
        g.n,
        g.edges.len(),
        g.dangling_count(),
        std::fs::metadata(&mm).map(|m| m.len() as f64 / 1e6).unwrap_or(0.0)
    );

    // ---- 2. pure-Spark PageRank on sparksim
    println!("== pure-Spark PageRank ({iters} iterations, checkpoint every 10)");
    let sc = Spark::new(workers, 4 * workers);
    let t = Instant::now();
    let pure = pure_spark_pagerank(&sc, &g.edges, iters, 10);
    let pure_secs = t.elapsed().as_secs_f64();
    println!(
        "   {:.2} s end-to-end  ({} shuffles, {} shuffle records, {} tasks)",
        pure_secs,
        sc.stats().shuffles.load(std::sync::atomic::Ordering::Relaxed),
        sc.stats().shuffle_records.load(std::sync::atomic::Ordering::Relaxed),
        sc.stats().tasks.load(std::sync::atomic::Ordering::Relaxed),
    );
    println!("   (canonical formulation: ranks unnormalised, {} scored vertices)", pure.len());

    // ---- 3. accelerated PageRank: LPF hooked from the same workers
    let runtime = Runtime::global().ok();
    let rows_per = g.n.div_ceil(workers);
    let mut per_block = vec![0usize; workers];
    for &(_, d) in &g.edges {
        per_block[(d as usize) / rows_per] += 1;
    }
    let max_block = per_block.iter().copied().max().unwrap_or(0);
    // aot builds pads of 8n/p and 16n/p; pick the smallest that fits
    let nnz_pad = [8 * g.n / workers, 16 * g.n / workers]
        .into_iter()
        .find(|&pad| max_block <= pad)
        .unwrap_or_else(|| max_block.next_power_of_two());
    let compute = match &runtime {
        Some(rt) => {
            let name = format!("spmv_{}_{}_{}", nnz_pad, g.n, g.n.div_ceil(workers));
            if rt.manifest().get(&name).is_some() {
                println!("== accelerated PageRank (LPF via hook; PJRT artifact {name})");
                Compute::Artifacts(rt.clone())
            } else {
                println!("== accelerated PageRank (LPF via hook; native compute — no artifact {name})");
                Compute::Native
            }
        }
        None => {
            println!("== accelerated PageRank (LPF via hook; native — run `make artifacts`)");
            Compute::Native
        }
    };
    let sc2 = Spark::new(workers, 4 * workers);
    let t = Instant::now();
    let acc = accelerated_pagerank(&sc2, &g, compute.clone(), 0.85, 1e-7, 60, nnz_pad, "e2e")
        .expect("accelerated pagerank");
    let acc_secs = t.elapsed().as_secs_f64();
    println!(
        "   {:.2} s end-to-end, n_eps = {} iterations to eps = 1e-7, residual = {:.2e}",
        acc_secs, acc.iters, acc.residual
    );
    // also measure the native-compute variant: on this container's old
    // xla_extension CPU backend the artifact SpMV is scatter-bound
    // (EXPERIMENTS.md §Perf), so the headline uses the faster local
    // compute — the LPF communication layer is identical in both
    let sc3 = Spark::new(workers, 4 * workers);
    let t = Instant::now();
    let acc_native =
        accelerated_pagerank(&sc3, &g, Compute::Native, 0.85, 1e-7, 60, nnz_pad, "e2e-nat")
            .expect("accelerated pagerank (native)");
    let acc_native_secs = t.elapsed().as_secs_f64();
    println!(
        "   native-compute variant: {:.2} s end-to-end ({} iterations)",
        acc_native_secs, acc_native.iters
    );

    // ---- 4. verification + headline metric
    let (want, _) = pagerank_serial(&g, 0.85, 1e-7, 60);
    let mut max_err = 0f32;
    for v in 0..g.n {
        max_err = max_err.max((acc.ranks[v] - want[v]).abs());
    }
    println!("   verification vs serial oracle: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-5, "verification failed");
    let pure_per_iter = pure_secs / iters as f64;
    let acc_per_iter = acc_secs / acc.iters.max(1) as f64;
    let nat_per_iter = acc_native_secs / acc_native.iters.max(1) as f64;
    println!("== headline (Table-4 shape):");
    println!("   pure Spark               : {:.4} s/iteration", pure_per_iter);
    println!("   LPF via hook (artifacts) : {:.4} s/iteration", acc_per_iter);
    println!("   LPF via hook (native)    : {:.4} s/iteration", nat_per_iter);
    println!("   speedup                  : {:.0}x per iteration", pure_per_iter / nat_per_iter.max(1e-12));
    std::fs::remove_file(mm).ok();
    println!("OK");
}
