//! Tour of the LPF collectives library (paper §6 mentions an LPF-based
//! collectives library as one of the higher-level interfaces LPF is
//! expressive enough to host).
//!
//! Run: `cargo run --release --example collectives_tour`

use lpf::collectives::Coll;
use lpf::core::{Args, SYNC_DEFAULT};
use lpf::ctx::{exec, Platform, Root};

fn main() {
    let p = 4;
    let root = Root::new(Platform::shared()).with_max_procs(p);
    exec(
        &root,
        p,
        |ctx, _| {
            ctx.bootstrap(8, 8 * ctx.p() as usize).unwrap();
            let coll = Coll::new(ctx, 1024).unwrap();
            ctx.sync(SYNC_DEFAULT).unwrap();
            let me = ctx.pid();

            // broadcast
            let mut data = if me == 0 { [314u64, 159] } else { [0; 2] };
            coll.broadcast(ctx, 0, &mut data).unwrap();
            assert_eq!(data, [314, 159]);

            // allgather
            let mut all = [0u32; 4];
            coll.allgather(ctx, &[me * me], &mut all).unwrap();
            assert_eq!(all, [0, 1, 4, 9]);

            // allreduce (sum) and scan (prefix sum)
            let mut sum = [0u64];
            coll.allreduce(ctx, &[me as u64 + 1], &mut sum, |a, b| a + b).unwrap();
            assert_eq!(sum[0], 10);
            let mut pfx = [0u64];
            coll.scan(ctx, &[me as u64 + 1], &mut pfx, |a, b| a + b).unwrap();
            assert_eq!(pfx[0], (1..=me as u64 + 1).sum());

            // alltoall (transpose)
            let send: Vec<u32> = (0..4).map(|k| me * 10 + k).collect();
            let mut recv = [0u32; 4];
            coll.alltoall(ctx, &send, &mut recv).unwrap();
            assert_eq!(recv.to_vec(), (0..4).map(|k| k * 10 + me).collect::<Vec<_>>());

            if me == 0 {
                println!("broadcast / allgather / allreduce / scan / alltoall: all OK on p={}", ctx.p());
                let m = ctx.probe();
                println!(
                    "probe: p={} g={:.1} ns/word l={:.1} µs (word=8B)",
                    m.p,
                    m.at_word(8).g_ns,
                    m.at_word(8).l_ns / 1e3
                );
            }
        },
        Args::none(),
    )
    .unwrap();
}
