//! Quickstart: the paper's Algorithm 1 + Algorithm 2 in Rust.
//!
//! A sequential `main` launches an SPMD function with `exec` (Algorithm 1),
//! which bootstraps buffers, distributes a matrix size from the root,
//! broadcasts errors with CRCW write-conflict resolution, and returns an
//! error code through the args/output mechanism (Algorithm 2).
//!
//! Run: `cargo run --release --example quickstart -- 1000 500`

use lpf::core::{Args, MSG_DEFAULT, SYNC_DEFAULT};
use lpf::ctx::{exec, Context, Platform, Root};

const OK: u32 = 0;
const ILLEGAL_INPUT: u32 = 1;

/// Algorithm 2: the 'hello world' SPMD function.
fn spmd(ctx: &mut Context, args: Args) -> u32 {
    let p = ctx.p();
    let s = ctx.pid();

    // allocate and activate LPF buffers
    ctx.resize_memory_register(3).unwrap();
    ctx.resize_message_queue(2 * p as usize).unwrap();
    ctx.sync(SYNC_DEFAULT).unwrap();

    // register memory areas for communication
    let s_lerr = ctx.register_local(4).unwrap();
    let s_gerr = ctx.register_global(4).unwrap();
    let s_mdim = ctx.register_global(8).unwrap();

    // root seeds the matrix size from args; everyone else fetches it
    if s == 0 && args.input.len() == 8 {
        ctx.write_slot(s_mdim, 0, &args.input).unwrap();
    }
    if s != 0 {
        ctx.get(0, s_mdim, 0, s_mdim, 0, 8, MSG_DEFAULT).unwrap();
    }
    ctx.sync(SYNC_DEFAULT).unwrap();

    // compute the local matrix size
    let mut mdim = [0u32; 2];
    ctx.read_typed(s_mdim, 0, &mut mdim).unwrap();
    let m_local = (mdim[0] as i64 + p as i64 - s as i64 - 1) / p as i64;
    let n = mdim[1] as i64;
    let lerr = if m_local <= 0 || n <= 0 { ILLEGAL_INPUT } else { OK };
    ctx.write_typed(s_lerr, 0, &[lerr]).unwrap();

    // broadcast errors using CRCW write-conflict resolution: every
    // erroring process puts its code into everyone's gerr — no buffer
    // needed, any winner is an error code (paper §2.1)
    if lerr != OK {
        for k in 0..p {
            ctx.put(s_lerr, 0, k, s_gerr, 0, 4, MSG_DEFAULT).unwrap();
        }
    }
    ctx.sync(SYNC_DEFAULT).unwrap();
    let mut gerr = [OK];
    ctx.read_typed(s_gerr, 0, &mut gerr).unwrap();

    if gerr[0] == OK {
        println!("pid {s}/{p}: my block is {m_local} x {n} — building matrix...");
    }

    // clean up & return the error code
    ctx.deregister(s_lerr).unwrap();
    ctx.deregister(s_gerr).unwrap();
    ctx.deregister(s_mdim).unwrap();
    gerr[0]
}

/// Algorithm 1: sequential main calling lpf_exec.
fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let rows: u32 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);
    let cols: u32 = argv.get(2).and_then(|s| s.parse().ok()).unwrap_or(500);
    let mut input = Vec::new();
    input.extend_from_slice(&rows.to_le_bytes());
    input.extend_from_slice(&cols.to_le_bytes());

    let root = Root::new(Platform::shared()); // LPF_ROOT
    let outs = exec(&root, lpf::core::MAX_P, spmd, Args::input(input)).unwrap();
    let out = outs[0];
    println!("exit code: {out}");
    std::process::exit(out as i32);
}
