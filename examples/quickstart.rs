//! Quickstart: the paper's Algorithm 1 + Algorithm 2 in Rust, written
//! against the typed superstep API (v2).
//!
//! A sequential `main` launches an SPMD function with `exec` (Algorithm 1),
//! which bootstraps buffers, distributes a matrix size from the root,
//! broadcasts errors with CRCW write-conflict resolution, and returns an
//! error code through the args/output mechanism (Algorithm 2).
//!
//! The raw twelve-primitive version of this same program is shown
//! side-by-side in README.md ("Migrating from the raw API"); the two are
//! byte-for-byte equivalent on the wire.
//!
//! Run: `cargo run --release --example quickstart -- 1000 500`

use lpf::core::Args;
use lpf::ctx::{Context, Platform, Root};
use lpf::pool::Pool;

const OK: u32 = 0;
const ILLEGAL_INPUT: u32 = 1;

/// Algorithm 2: the 'hello world' SPMD function.
fn spmd(ctx: &mut Context, args: Args) -> u32 {
    let p = ctx.p();
    let s = ctx.pid();

    // allocate and activate LPF buffers (resize register + queue + fence)
    ctx.bootstrap(3, 2 * p as usize).unwrap();

    // register typed memory areas for communication
    let s_lerr = ctx.alloc_local::<u32>(1).unwrap();
    let s_gerr = ctx.alloc_global::<u32>(1).unwrap();
    let s_mdim = ctx.alloc_global::<u32>(2).unwrap();

    // root seeds the matrix size from args; everyone else fetches it
    if s == 0 && args.input.len() == 8 {
        let rows = u32::from_le_bytes(args.input[0..4].try_into().unwrap());
        let cols = u32::from_le_bytes(args.input[4..8].try_into().unwrap());
        ctx.write(s_mdim, 0, &[rows, cols]).unwrap();
    }
    ctx.superstep(|ep| {
        if ep.pid() != 0 {
            ep.get_slice(0, s_mdim, 0, s_mdim, 0, 2)?;
        }
        Ok(())
    })
    .unwrap();

    // compute the local matrix size
    let mdim = ctx.read_vec(s_mdim).unwrap();
    let m_local = (mdim[0] as i64 + p as i64 - s as i64 - 1) / p as i64;
    let n = mdim[1] as i64;
    let lerr = if m_local <= 0 || n <= 0 { ILLEGAL_INPUT } else { OK };
    ctx.write(s_lerr, 0, &[lerr]).unwrap();

    // broadcast errors using CRCW write-conflict resolution: every
    // erroring process puts its code into everyone's gerr — no buffer
    // needed, any winner is an error code (paper §2.1)
    ctx.superstep(|ep| {
        if lerr != OK {
            for k in 0..ep.p() {
                ep.put_slice(s_lerr, 0, k, s_gerr, 0, 1)?;
            }
        }
        Ok(())
    })
    .unwrap();
    let gerr = ctx.read_vec(s_gerr).unwrap()[0];

    if gerr == OK {
        println!("pid {s}/{p}: my block is {m_local} x {n} — building matrix...");
    }

    // clean up & return the error code
    ctx.dealloc(s_lerr).unwrap();
    ctx.dealloc(s_gerr).unwrap();
    ctx.dealloc(s_mdim).unwrap();
    gerr
}

/// Algorithm 1: sequential main launching SPMD jobs — pool-first. A
/// [`Pool`] spawns the `p` processes once; every `exec` on it is a warm
/// job (no spawn, no fabric rebuild). For a single one-shot job,
/// `lpf::exec(&root, MAX_P, spmd, args)` remains available and is sugar
/// for exactly this with a transient pool.
fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let rows: u32 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);
    let cols: u32 = argv.get(2).and_then(|s| s.parse().ok()).unwrap_or(500);
    let mut input = Vec::new();
    input.extend_from_slice(&rows.to_le_bytes());
    input.extend_from_slice(&cols.to_le_bytes());

    let root = Root::new(Platform::shared()); // LPF_ROOT
    let p = lpf::core::MAX_P.min(8);
    let pool = Pool::new(root.platform().clone(), p); // spawn the team once

    // serve the request on the warm team (a server would loop here,
    // dispatching one job per incoming query at zero spawn cost)
    let outs = pool.exec(spmd, Args::input(input)).unwrap();
    let out = outs[0];
    println!("exit code: {out}");
    std::process::exit(out as i32);
}
