//! Porting a BSPlib program verbatim (paper §4.2: the BSPlib layer "enables
//! the use of a large body of BSP algorithms originally written for
//! BSPlib"). This is the classic BSPlib inner-product example: block
//! distribute two vectors, local dot products, allgather partial sums —
//! using the typed, element-indexed registrations (`push_reg_of`,
//! `put_at`, `read_local_at`), so the port carries no byte offsets.
//!
//! Run: `cargo run --release --example bsplib_port`

use lpf::bsplib::Bsp;
use lpf::core::Args;
use lpf::ctx::{exec, Platform, Root};

fn bspip(bsp: &mut Bsp, x: &[f64], y: &[f64]) -> f64 {
    let p = bsp.nprocs();
    // registered window for everyone's partial sum, one f64 per pid
    let partial = bsp.push_reg_of::<f64>(p as usize).unwrap();
    bsp.sync().unwrap();
    let local: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    // bsp_put my partial into slot pid of everyone (buffered put)
    for k in 0..p {
        bsp.put_at(k, &[local], partial, bsp.pid() as usize).unwrap();
    }
    bsp.sync().unwrap();
    let mut all = vec![0f64; p as usize];
    bsp.read_local_at(partial, 0, &mut all).unwrap();
    bsp.pop_reg_of(partial).unwrap();
    all.iter().sum()
}

fn main() {
    let n = 1 << 16;
    let p = 4;
    let root = Root::new(Platform::shared()).with_max_procs(p);
    let outs = exec(
        &root,
        p,
        move |ctx, _| {
            let s = ctx.pid() as usize;
            let pp = ctx.p() as usize;
            let mut bsp = Bsp::begin(ctx, 4, 2 * pp + 2).unwrap();
            bsp.sync().unwrap();
            // block distribution of x[i] = i, y[i] = 2
            let chunk = n / pp;
            let x: Vec<f64> = (s * chunk..(s + 1) * chunk).map(|i| i as f64).collect();
            let y = vec![2.0f64; chunk];
            let ip = bspip(&mut bsp, &x, &y);
            bsp.end().unwrap();
            ip
        },
        Args::none(),
    )
    .unwrap();
    let want: f64 = (0..n).map(|i| i as f64 * 2.0).sum();
    for (pid, ip) in outs.iter().enumerate() {
        assert!((ip - want).abs() < 1e-6, "pid {pid}");
    }
    println!("bsplib inner product: {} == {} on all pids — OK", outs[0], want);
}
